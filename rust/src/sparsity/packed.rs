//! Packed N:M storage and the sparse inference kernels that consume it.
//!
//! Everywhere else in the crate, N:M sparsity is *simulated*: forward passes
//! multiply dense weights by a dense {0,1} mask, so every pruned slot still
//! costs a multiply-add and 4 bytes of memory traffic. This module is the
//! deployment half of the paper's pitch — once a mask is learned, the model
//! is exported to a compressed form that stores **only the kept values**,
//! and inference runs kernels that *skip* pruned slots instead of
//! multiplying by them (the CPU analog of what A100 sparse tensor cores do
//! with 2:4 metadata).
//!
//! # Storage format
//!
//! [`PackedNmTensor`] stores, per group of `M` consecutive elements along
//! the last axis:
//!
//! * the `N` kept values, in ascending slot order (`values`, f32, raw bits
//!   preserved — NaN/±inf payloads survive packing), and
//! * an `M`-bit *index code* whose bit `j` marks slot `j` as kept
//!   (`codes`, a dense little-endian bitstream, `M` bits per group).
//!
//! For 2:4 that is 4 code bits per group — 2 bits per kept slot, the same
//! metadata budget as the A100's 2-bit column indices — plus 8 value bytes,
//! i.e. 8.5 bytes instead of 16 (0.53× the dense footprint). Groups never
//! cross row boundaries; a last axis that is **not** divisible by `M` gets
//! one trailing partial group per row that is stored dense (every slot
//! kept), so arbitrary shapes round-trip losslessly.
//!
//! # Kernels
//!
//! [`packed_matvec`] / [`packed_matmul`] / [`packed_matmul_into`] compute
//! `x @ W` against a packed `W` **bit-for-bit identically** to the dense
//! [`crate::tensor::matmul`] over the masked weights (on finite inputs):
//! contributions accumulate in the same ascending-`k` order, and the terms
//! they skip are exactly the ones the dense kernel either skips
//! (`x[k] == 0`) or adds as `±0.0` no-ops (pruned slots). The batched path
//! transposes batch-row tiles so each kept value is applied to a whole tile
//! of samples through the runtime-dispatched SIMD axpy
//! ([`crate::sparsity::dispatch`] — the tile width follows the detected
//! vector width, e.g. 16 rows on AVX2) — half the vector work of the dense
//! masked product at 2:4 — and streams the packed weights (≈0.53× the
//! bytes) once per tile. Batch lanes are independent accumulators, so
//! vectorizing across them never reassociates any single dot product.
//!
//! The **backward** kernels close the training loop for frozen-mask
//! fine-tuning: [`packed_matmul_at`] computes the compact weight gradient
//! `dW = Aᵀ·Δ` restricted to kept slots (pruned coordinates are never
//! materialized), and [`packed_matmul_bt`] computes the activation gradient
//! `dA = Δ·Wᵀ` streaming the compressed weights. Both are bit-for-bit equal
//! to the dense kernels over the masked weights — see the function docs for
//! the accumulation-order argument — so a packed fine-tune step matches the
//! dense masked step exactly on every kept coordinate
//! (`rust/tests/packed_finetune.rs`).
//!
//! The serving layer on top of these kernels lives in
//! [`crate::coordinator::serve`], the fine-tuning loop in
//! [`crate::coordinator::finetune`]; `cargo bench --bench substrate` records
//! packed-vs-dense forward throughput to `BENCH_inference.json` and
//! fine-tune step throughput to `BENCH_finetune.json`.

use super::dispatch::Dispatch;
use super::{select_keep, NmRatio};
use crate::tensor::Tensor;

/// Largest group size the packed format supports (index codes are kept in a
/// `u32` per group).
pub const MAX_PACKED_M: usize = 32;

/// Caller-owned scratch for the batch-tiled kernels
/// ([`packed_matmul_rows_into`], [`packed_matmul_bt_tiled_into`]).
///
/// The tiled kernels transpose a `tile`-row panel of the batch before
/// streaming the packed weights; that panel plus the tile of output
/// accumulators used to be `vec!`'d on every invocation, which put an
/// allocation on every serve-path call. Constructing a `PackedScratch` is
/// free (empty vecs); each kernel grows the buffers it needs **before** its
/// hot loop and steady-state reuse is allocation-free once the buffers have
/// reached the layer's working-set size.
#[derive(Debug, Default)]
pub struct PackedScratch {
    /// Transposed input panel (`rows * tile`, forward kernel).
    xt: Vec<f32>,
    /// Output accumulator panel (`cols * tile`, forward kernel).
    yt: Vec<f32>,
    /// Transposed delta panel (`k * tile`, backward-`bt` kernel).
    dt: Vec<f32>,
    /// Lane-group accumulators (`5 * tile`, backward-`bt` kernel: the four
    /// `j % 4` partitions plus the tail partition).
    acc: Vec<f32>,
}

impl PackedScratch {
    /// An empty scratch; buffers grow on first kernel use.
    pub fn new() -> Self {
        Self::default()
    }
}

/// Grow `buf` to at least `len` and return the `len`-prefix. Called before
/// the kernels' hot loops, so steady-state iterations never allocate.
fn grown(buf: &mut Vec<f32>, len: usize) -> &mut [f32] {
    if buf.len() < len {
        buf.resize(len, 0.0);
    }
    &mut buf[..len]
}

/// A tensor stored in compressed N:M form: kept values + per-group index
/// codes (see the [`crate::sparsity::packed`] module docs for the layout).
///
/// # Examples
///
/// ```
/// use step_nm::sparsity::{NmRatio, PackedNmTensor};
/// use step_nm::tensor::Tensor;
///
/// let w = Tensor::new(&[1, 8], vec![0.1, -3.0, 2.0, 0.5, 1.0, -1.0, 0.2, 0.0]);
/// let packed = PackedNmTensor::pack(&w, NmRatio::new(2, 4));
///
/// // Only the 2 kept values per group of 4 are stored…
/// assert_eq!(packed.n_values(), 4);
/// // …and unpacking reconstructs the masked tensor exactly.
/// assert_eq!(packed.unpack().data(), &[0.0, -3.0, 2.0, 0.0, 1.0, -1.0, 0.0, 0.0]);
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct PackedNmTensor {
    shape: Vec<usize>,
    ratio: NmRatio,
    /// Kept values, group-major, ascending slot order within each group.
    values: Vec<f32>,
    /// `M`-bit keep codes, one per group, packed little-endian.
    codes: Vec<u8>,
}

/// Append an `m`-bit group code to the little-endian bitstream.
fn push_bits(codes: &mut Vec<u8>, bitlen: &mut usize, code: u32, m: usize) {
    for j in 0..m {
        let pos = *bitlen + j;
        if pos / 8 == codes.len() {
            codes.push(0);
        }
        if (code >> j) & 1 == 1 {
            codes[pos / 8] |= 1 << (pos % 8);
        }
    }
    *bitlen += m;
}

/// Read the `m`-bit group code at `bitpos` from the bitstream.
#[inline]
fn read_bits(codes: &[u8], bitpos: usize, m: usize) -> u32 {
    debug_assert!(m <= MAX_PACKED_M);
    let byte = bitpos >> 3;
    let shift = bitpos & 7;
    let mut win = 0u64;
    for (k, &b) in codes[byte..].iter().take(5).enumerate() {
        win |= (b as u64) << (8 * k);
    }
    ((win >> shift) & ((1u64 << m) - 1)) as u32
}

impl PackedNmTensor {
    /// Pack the N:M-masked form of `w`: selection uses the exact
    /// [`nm_mask`](super::nm_mask) rule (largest `N` by `|x|`, ties and
    /// all-NaN remainders to the lowest index), so
    /// `packed.unpack() == apply_nm(w)` always holds — see
    /// [`unpack`](Self::unpack) for the doctested round trip.
    ///
    /// A last axis not divisible by `M` is legal: each row's trailing
    /// partial group is stored dense. Panics if `M >` [`MAX_PACKED_M`] or
    /// the last axis is empty.
    pub fn pack(w: &Tensor, ratio: NmRatio) -> Self {
        let (n, m) = (ratio.n, ratio.m);
        assert!(m <= MAX_PACKED_M, "packed N:M supports M ≤ {MAX_PACKED_M} (got {m})");
        let cols = w.last_dim();
        assert!(cols > 0, "cannot pack an empty last axis (shape {:?})", w.shape());
        let rows = w.rows_2d();
        let full = cols / m;
        let tail = cols % m;
        let wd = w.data();
        let mut values = Vec::with_capacity(rows * (full * n + tail));
        let mut codes: Vec<u8> = Vec::new();
        let mut bitlen = 0usize;
        let mut keep = [false; 64];
        for r in 0..rows {
            let row = &wd[r * cols..(r + 1) * cols];
            for g in 0..full {
                let group = &row[g * m..(g + 1) * m];
                select_keep(group, n, &mut keep);
                let mut code = 0u32;
                for (j, &x) in group.iter().enumerate() {
                    if keep[j] {
                        code |= 1 << j;
                        values.push(x);
                    }
                }
                push_bits(&mut codes, &mut bitlen, code, m);
            }
            if tail > 0 {
                // Partial trailing group: stored dense (every slot kept).
                let mut code = 0u32;
                for (j, &x) in row[full * m..].iter().enumerate() {
                    code |= 1 << j;
                    values.push(x);
                }
                push_bits(&mut codes, &mut bitlen, code, m);
            }
        }
        Self { shape: w.shape().to_vec(), ratio, values, codes }
    }

    /// Rebuild a packed tensor from its serialized parts (the checkpoint
    /// import path), validating lengths and per-group code populations.
    pub fn from_parts(
        shape: Vec<usize>,
        ratio: NmRatio,
        values: Vec<f32>,
        codes: Vec<u8>,
    ) -> anyhow::Result<Self> {
        let (n, m) = (ratio.n, ratio.m);
        anyhow::ensure!(m <= MAX_PACKED_M, "packed N:M supports M ≤ {MAX_PACKED_M} (got {m})");
        let cols = shape.last().copied().unwrap_or(0);
        anyhow::ensure!(cols > 0, "packed tensor needs a non-empty last axis (shape {shape:?})");
        let numel: usize = shape.iter().product();
        let rows = numel / cols;
        let full = cols / m;
        let tail = cols % m;
        let groups_per_row = full + usize::from(tail > 0);
        let expect_values = rows * (full * n + tail);
        let expect_bytes = (rows * groups_per_row * m + 7) / 8;
        anyhow::ensure!(
            values.len() == expect_values,
            "packed values length {} != expected {expect_values} for shape {shape:?} at {ratio}",
            values.len()
        );
        anyhow::ensure!(
            codes.len() == expect_bytes,
            "packed code stream {} bytes != expected {expect_bytes}",
            codes.len()
        );
        // Every full group must keep exactly N slots; tail groups keep all.
        let mut bitpos = 0usize;
        for _r in 0..rows {
            for _g in 0..full {
                let code = read_bits(&codes, bitpos, m);
                bitpos += m;
                anyhow::ensure!(
                    code.count_ones() as usize == n,
                    "corrupt packed code: group keeps {} of {m}, want {n}",
                    code.count_ones()
                );
            }
            if tail > 0 {
                let code = read_bits(&codes, bitpos, m);
                bitpos += m;
                anyhow::ensure!(
                    code == (1u32 << tail) - 1,
                    "corrupt packed tail code {code:#x} (tail width {tail})"
                );
            }
        }
        Ok(Self { shape, ratio, values, codes })
    }

    // ---- accessors --------------------------------------------------------

    /// Logical (dense) shape.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// The N:M ratio this tensor is packed at.
    pub fn ratio(&self) -> NmRatio {
        self.ratio
    }

    /// Stored (kept) value count.
    pub fn n_values(&self) -> usize {
        self.values.len()
    }

    /// Element count of the dense form.
    pub fn dense_numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// Raw kept values (serialization).
    pub fn values(&self) -> &[f32] {
        &self.values
    }

    /// Kept values, mutable — the frozen-mask fine-tuning hook: an
    /// optimizer may update the kept values in place while the index codes
    /// (the learned mask) stay structurally untouched. See
    /// [`crate::coordinator::finetune::FinetuneSession`].
    pub fn values_mut(&mut self) -> &mut [f32] {
        &mut self.values
    }

    /// Stored values per logical row — identical for every row: `N` per
    /// full group plus the dense tail. Row `r`'s values occupy
    /// `r * values_per_row() .. (r + 1) * values_per_row()` of
    /// [`values`](Self::values).
    pub fn values_per_row(&self) -> usize {
        let cols = self.cols();
        (cols / self.ratio.m) * self.ratio.n + cols % self.ratio.m
    }

    /// The dense column index of every stored value, in storage order —
    /// the decoded form of the code bitstream (one `u32` per kept value,
    /// ascending within each row). The backward kernels take this as a
    /// caller-cached argument so hot loops never re-decode the bitstream.
    pub fn col_indices(&self) -> Vec<u32> {
        let m = self.ratio.m;
        let cols = self.cols();
        let full = cols / m;
        let tail = cols % m;
        let mut out = Vec::with_capacity(self.values.len());
        let mut bitpos = 0usize;
        for _r in 0..self.rows() {
            for g in 0..full {
                let mut code = read_bits(&self.codes, bitpos, m);
                bitpos += m;
                let base = (g * m) as u32;
                while code != 0 {
                    out.push(base + code.trailing_zeros());
                    code &= code - 1;
                }
            }
            if tail > 0 {
                bitpos += m; // tail code is all-ones by construction
                for j in 0..tail {
                    out.push((full * m + j) as u32);
                }
            }
        }
        debug_assert_eq!(out.len(), self.values.len());
        out
    }

    /// Gather a same-shape dense tensor at this tensor's kept coordinates,
    /// in storage order — e.g. compacting a frozen `v*` or a dense
    /// optimizer state onto the packed support when entering fine-tuning.
    pub fn compact_like(&self, dense: &Tensor) -> Vec<f32> {
        assert_eq!(
            dense.shape(),
            self.shape.as_slice(),
            "compact_like shape mismatch {:?} vs {:?}",
            dense.shape(),
            self.shape
        );
        let cols = self.cols();
        let vpr = self.values_per_row();
        let dd = dense.data();
        self.col_indices()
            .iter()
            .enumerate()
            .map(|(vc, &j)| dd[(vc / vpr) * cols + j as usize])
            .collect()
    }

    /// Raw index-code bitstream (serialization).
    pub fn codes(&self) -> &[u8] {
        &self.codes
    }

    /// Payload bytes of the packed form (values + index codes).
    pub fn packed_bytes(&self) -> usize {
        self.values.len() * 4 + self.codes.len()
    }

    /// Payload bytes of the dense form.
    pub fn dense_bytes(&self) -> usize {
        self.dense_numel() * 4
    }

    /// `packed_bytes / dense_bytes` — 8.5/16 = 0.53125 for 2:4.
    pub fn compression(&self) -> f64 {
        self.packed_bytes() as f64 / self.dense_bytes().max(1) as f64
    }

    /// Rows when viewed as 2-D `[rows, last_dim]`.
    fn rows(&self) -> usize {
        self.dense_numel() / self.cols()
    }

    /// Size of the grouped (last) axis.
    fn cols(&self) -> usize {
        self.shape.last().copied().unwrap_or(1)
    }

    // ---- unpack -----------------------------------------------------------

    /// Reconstruct the dense masked tensor (`apply_nm` of the packed source).
    ///
    /// The round trip is lossless: kept values come back bit-exact (NaN and
    /// ±inf payloads included), pruned slots come back as `+0.0`.
    ///
    /// # Examples
    ///
    /// ```
    /// use step_nm::sparsity::{apply_nm, NmRatio, PackedNmTensor};
    /// use step_nm::tensor::Tensor;
    /// use step_nm::rng::Pcg64;
    ///
    /// let w = Tensor::randn(&[4, 16], &mut Pcg64::new(7), 0.0, 1.0);
    /// let ratio = NmRatio::new(2, 4);
    /// let packed = PackedNmTensor::pack(&w, ratio);
    /// assert_eq!(packed.unpack(), apply_nm(&w, ratio));
    /// ```
    pub fn unpack(&self) -> Tensor {
        let mut out = Tensor::zeros(&self.shape);
        self.unpack_into(&mut out);
        out
    }

    /// Allocation-free [`unpack`](Self::unpack) into an existing tensor.
    pub fn unpack_into(&self, out: &mut Tensor) {
        assert_eq!(
            out.shape(),
            self.shape.as_slice(),
            "unpack_into shape mismatch {:?} vs {:?}",
            out.shape(),
            self.shape
        );
        let m = self.ratio.m;
        let cols = self.cols();
        let rows = self.rows();
        let full = cols / m;
        let tail = cols % m;
        let od = out.data_mut();
        od.fill(0.0);
        let mut vc = 0usize;
        let mut bitpos = 0usize;
        for r in 0..rows {
            let row = &mut od[r * cols..(r + 1) * cols];
            for g in 0..full {
                let mut code = read_bits(&self.codes, bitpos, m);
                bitpos += m;
                let base = g * m;
                while code != 0 {
                    let j = code.trailing_zeros() as usize;
                    row[base + j] = self.values[vc];
                    vc += 1;
                    code &= code - 1;
                }
            }
            if tail > 0 {
                bitpos += m; // tail code is all-ones by construction
                for x in row[full * m..].iter_mut() {
                    *x = self.values[vc];
                    vc += 1;
                }
            }
        }
        debug_assert_eq!(vc, self.values.len());
    }
}

// ---------------------------------------------------------------------------
// sparse kernels
// ---------------------------------------------------------------------------

/// `y = x @ W` for packed `W` (logical `[in, out]`), skipping pruned slots.
///
/// Bit-identical to the matching row of [`crate::tensor::matmul`] against
/// `W`'s dense masked form on finite inputs: contributions accumulate in
/// ascending input order, rows with `x[i] == 0.0` are skipped exactly like
/// the dense kernel's zero-activation skip, and the pruned-slot terms the
/// dense kernel adds are `±0.0` no-ops.
pub fn packed_matvec(x: &[f32], w: &PackedNmTensor, y: &mut [f32]) {
    let (n, m) = (w.ratio.n, w.ratio.m);
    let rows = w.rows();
    let cols = w.cols();
    assert_eq!(x.len(), rows, "matvec input {} vs weight rows {rows}", x.len());
    assert_eq!(y.len(), cols, "matvec output {} vs weight cols {cols}", y.len());
    y.fill(0.0);
    let full = cols / m;
    let tail = cols % m;
    let values_per_row = full * n + tail;
    let groups_per_row = full + usize::from(tail > 0);
    let vals = &w.values[..];
    let codes = &w.codes[..];
    let mut vc = 0usize;
    let mut gi = 0usize; // global group index; the code sits at bit gi*m
    if m == 4 && tail == 0 {
        // Hot path (2:4 and friends): one nibble of code per group.
        for &a in x {
            if a == 0.0 {
                vc += values_per_row;
                gi += full;
                continue;
            }
            for chunk in y.chunks_exact_mut(4) {
                let mut code = (codes[gi >> 1] >> ((gi & 1) * 4)) & 0x0F;
                gi += 1;
                while code != 0 {
                    let j = code.trailing_zeros() as usize;
                    chunk[j] += a * vals[vc];
                    vc += 1;
                    code &= code - 1;
                }
            }
        }
        return;
    }
    for &a in x {
        if a == 0.0 {
            vc += values_per_row;
            gi += groups_per_row;
            continue;
        }
        for g in 0..full {
            let mut code = read_bits(codes, gi * m, m);
            gi += 1;
            let base = g * m;
            while code != 0 {
                let j = code.trailing_zeros() as usize;
                y[base + j] += a * vals[vc];
                vc += 1;
                code &= code - 1;
            }
        }
        if tail > 0 {
            gi += 1;
            for yj in y[full * m..].iter_mut() {
                *yj += a * vals[vc];
                vc += 1;
            }
        }
    }
}

/// `C = H @ W` for packed `W`: the row-major batched forward kernel.
pub fn packed_matmul(h: &Tensor, w: &PackedNmTensor) -> Tensor {
    let (batch, _) = h.as_2d();
    let mut c = Tensor::zeros(&[batch, w.cols()]);
    packed_matmul_into(h, w, &mut c);
    c
}

/// Allocation-conscious `C = H @ W` into a preallocated output.
///
/// Batches of ≥ one dispatch tile run the tiled kernel: `tile` input rows
/// (the width [`Dispatch::tile`] picks from the detected vector width) are
/// transposed so every kept weight value is applied to the whole tile with
/// one SIMD axpy, and the packed weight stream (values + codes) is read
/// once per tile instead of once per sample. Remainder rows fall back to
/// [`packed_matvec`]. Results are bit-identical to per-row
/// [`packed_matvec`] — and hence to the dense masked matmul — at every tile
/// width, because batch lanes are independent accumulators.
pub fn packed_matmul_into(h: &Tensor, w: &PackedNmTensor, out: &mut Tensor) {
    let (batch, k) = h.as_2d();
    assert_eq!(k, w.rows(), "inner dims {k} vs {}", w.rows());
    packed_matmul_rows(h.data(), batch, w, out);
}

/// `C = H @ W` where `H` is a **borrowed** row-major `[batch, w.rows()]`
/// slice — the copy-free entry the threaded serving shards use (no `Tensor`
/// is materialized per shard). Allocates its own scratch;
/// [`packed_matmul_rows_into`] is the allocation-free variant for hot loops.
pub fn packed_matmul_rows(h: &[f32], batch: usize, w: &PackedNmTensor, out: &mut Tensor) {
    packed_matmul_rows_into(h, batch, w, out, &mut PackedScratch::new());
}

/// [`packed_matmul_rows`] with caller-owned [`PackedScratch`]: the serve
/// hot path threads one scratch through every layer so steady-state
/// forwards are allocation-free.
pub fn packed_matmul_rows_into(
    h: &[f32],
    batch: usize,
    w: &PackedNmTensor,
    out: &mut Tensor,
    scratch: &mut PackedScratch,
) {
    let (n, m) = (w.ratio.n, w.ratio.m);
    let rows = w.rows();
    let cols = w.cols();
    let k = rows;
    assert_eq!(h.len(), batch * rows, "input slice {} vs {batch}x{rows}", h.len());
    assert_eq!(
        out.shape(),
        &[batch, cols],
        "out shape {:?} vs [{batch}, {cols}]",
        out.shape()
    );
    let full = cols / m;
    let tail = cols % m;
    let values_per_row = full * n + tail;
    let groups_per_row = full + usize::from(tail > 0);
    let vals = &w.values[..];
    let codes = &w.codes[..];
    let hd = h;
    let od = out.data_mut();
    let disp = Dispatch::active();
    let tile = disp.tile();
    let mut b0 = 0usize;
    if batch >= tile {
        // Scratch growth happens here, before the tile loop — steady-state
        // iterations are allocation-free.
        let xt = grown(&mut scratch.xt, rows * tile);
        let yt = grown(&mut scratch.yt, cols * tile);
        while b0 + tile <= batch {
            // Transpose the tile: xt[i][t] = h[b0 + t][i], contiguous in t.
            for t in 0..tile {
                let hrow = &hd[(b0 + t) * k..(b0 + t + 1) * k];
                for (i, &v) in hrow.iter().enumerate() {
                    xt[i * tile + t] = v;
                }
            }
            yt.fill(0.0);
            // Stream the packed weights once for the whole tile. Each kept
            // value hits all `tile` batch lanes with one SIMD axpy — the
            // lanes are independent accumulators, so no dot product is
            // reassociated at any tile width.
            let mut vc = 0usize;
            let mut gi = 0usize;
            for i in 0..rows {
                let xi = &xt[i * tile..(i + 1) * tile];
                if xi.iter().all(|&v| v == 0.0) {
                    vc += values_per_row;
                    gi += groups_per_row;
                    continue;
                }
                if m == 4 && tail == 0 {
                    for g in 0..full {
                        let mut code = (codes[gi >> 1] >> ((gi & 1) * 4)) & 0x0F;
                        gi += 1;
                        while code != 0 {
                            let j = g * 4 + code.trailing_zeros() as usize;
                            let v = vals[vc];
                            vc += 1;
                            disp.axpy(&mut yt[j * tile..(j + 1) * tile], xi, v);
                            code &= code - 1;
                        }
                    }
                } else {
                    for g in 0..full {
                        let mut code = read_bits(codes, gi * m, m);
                        gi += 1;
                        while code != 0 {
                            let j = g * m + code.trailing_zeros() as usize;
                            let v = vals[vc];
                            vc += 1;
                            disp.axpy(&mut yt[j * tile..(j + 1) * tile], xi, v);
                            code &= code - 1;
                        }
                    }
                    if tail > 0 {
                        gi += 1;
                        for j in full * m..cols {
                            let v = vals[vc];
                            vc += 1;
                            disp.axpy(&mut yt[j * tile..(j + 1) * tile], xi, v);
                        }
                    }
                }
            }
            // Write the tile back row-major.
            for t in 0..tile {
                let orow = &mut od[(b0 + t) * cols..(b0 + t + 1) * cols];
                for (j, o) in orow.iter_mut().enumerate() {
                    *o = yt[j * tile + t];
                }
            }
            b0 += tile;
        }
    }
    for b in b0..batch {
        packed_matvec(&hd[b * k..(b + 1) * k], w, &mut od[b * cols..(b + 1) * cols]);
    }
}

// ---------------------------------------------------------------------------
// backward kernels (frozen-mask fine-tuning)
// ---------------------------------------------------------------------------

/// Compact weight gradient `dW = Aᵀ·Δ` restricted to the kept slots of a
/// packed `W` — the packed backward kernel for the weight gradient.
///
/// `a` is the layer input `[batch, w.rows()]`, `delta` the output gradient
/// `[batch, w.cols()]`; the result is aligned with
/// [`PackedNmTensor::values`] storage order (`n_values()` scalars), so the
/// gradient never materializes a pruned coordinate.
///
/// **Bit-identical** to [`crate::tensor::matmul_at`] at every kept
/// coordinate: both accumulate over the batch in ascending order and skip
/// zero activations (`a[b][i] == 0.0`), so each kept scalar sees the exact
/// same f32 additions in the exact same order.
pub fn packed_matmul_at(a: &Tensor, delta: &Tensor, w: &PackedNmTensor) -> Vec<f32> {
    let mut gv = vec![0f32; w.n_values()];
    packed_matmul_at_into(a, delta, w, &w.col_indices(), &mut gv);
    gv
}

/// Allocation-free [`packed_matmul_at`]: `cols_idx` must be
/// [`PackedNmTensor::col_indices`] of `w` (cached by the caller so hot
/// loops never re-decode the bitstream), `gv` the compact output.
pub fn packed_matmul_at_into(
    a: &Tensor,
    delta: &Tensor,
    w: &PackedNmTensor,
    cols_idx: &[u32],
    gv: &mut [f32],
) {
    let (batch, in_dim) = a.as_2d();
    let (batch2, out_dim) = delta.as_2d();
    assert_eq!(batch, batch2, "batch dims {batch} vs {batch2}");
    assert_eq!(in_dim, w.rows(), "input dim {in_dim} vs weight rows {}", w.rows());
    assert_eq!(out_dim, w.cols(), "delta dim {out_dim} vs weight cols {}", w.cols());
    assert_eq!(cols_idx.len(), w.n_values(), "col index cache length");
    assert_eq!(gv.len(), w.n_values(), "compact gradient length");
    let vpr = w.values_per_row();
    let ad = a.data();
    let dd = delta.data();
    gv.fill(0.0);
    for b in 0..batch {
        let arow = &ad[b * in_dim..(b + 1) * in_dim];
        let drow = &dd[b * out_dim..(b + 1) * out_dim];
        for (i, &aik) in arow.iter().enumerate() {
            if aik == 0.0 {
                // matches matmul_at's zero-activation skip (ReLU inputs)
                continue;
            }
            let s = i * vpr;
            for (g, &j) in gv[s..s + vpr].iter_mut().zip(&cols_idx[s..s + vpr]) {
                *g += aik * drow[j as usize];
            }
        }
    }
}

/// Activation gradient `dA = Δ·Wᵀ` against a packed `W`, streaming the
/// compressed weights — the packed backward kernel for the input gradient.
///
/// **Bit-identical** to [`crate::tensor::matmul_bt`] over the dense masked
/// form of `w` on finite `delta` inputs (the same qualifier the forward
/// kernels carry — a non-finite delta entry times a pruned `±0.0` slot
/// would produce NaN in the dense kernel but is skipped here): the dense
/// kernel folds column `j` into accumulator `j % 4` (tail columns past the
/// last 4-chunk into a scalar), and with finite inputs a pruned slot only
/// ever adds `±0.0` to an accumulator that is never `-0.0` — a strict
/// no-op. This kernel reproduces the same accumulator assignment from the
/// decoded column indices and simply skips those no-op terms, so every
/// accumulator (and hence the final left-to-right sum) carries the exact
/// same bits.
pub fn packed_matmul_bt(delta: &Tensor, w: &PackedNmTensor) -> Tensor {
    let (batch, _) = delta.as_2d();
    let mut out = Tensor::zeros(&[batch, w.rows()]);
    packed_matmul_bt_into(delta, w, &w.col_indices(), &mut out);
    out
}

/// Allocation-free [`packed_matmul_bt`] with a caller-cached `cols_idx`
/// (see [`PackedNmTensor::col_indices`]) and a preallocated output
/// `[batch, w.rows()]`. Allocates its own scratch for the batch-tiled
/// path; [`packed_matmul_bt_tiled_into`] is the variant for hot loops.
pub fn packed_matmul_bt_into(
    delta: &Tensor,
    w: &PackedNmTensor,
    cols_idx: &[u32],
    out: &mut Tensor,
) {
    packed_matmul_bt_tiled_into(delta, w, cols_idx, out, &mut PackedScratch::new());
}

/// [`packed_matmul_bt_into`] with caller-owned [`PackedScratch`] — the
/// batch-tiled activation-gradient kernel.
///
/// Batches of ≥ one dispatch tile transpose a `tile`-column delta panel and
/// keep **five accumulator rows per weight row** — the dense kernel's four
/// `j % 4` partitions plus its scalar tail — each `tile` lanes wide. Every
/// kept value lands in its partition through one SIMD axpy, and the final
/// per-lane reduction `acc0 + acc1 + acc2 + acc3 + tail` is the dense
/// kernel's left-to-right sum. Each partition receives exactly the terms
/// the scalar kernel gave it, in the same ascending-slot order, so the
/// result is bit-identical to the scalar path (and to
/// [`crate::tensor::matmul_bt`] over the masked weights on finite inputs —
/// the same qualifier [`packed_matmul_bt`] carries). Remainder batch rows
/// run the scalar per-row loop.
pub fn packed_matmul_bt_tiled_into(
    delta: &Tensor,
    w: &PackedNmTensor,
    cols_idx: &[u32],
    out: &mut Tensor,
    scratch: &mut PackedScratch,
) {
    let (batch, k) = delta.as_2d();
    let rows = w.rows();
    assert_eq!(k, w.cols(), "delta dim {k} vs weight cols {}", w.cols());
    assert_eq!(
        out.shape(),
        &[batch, rows],
        "out shape {:?} vs [{batch}, {rows}]",
        out.shape()
    );
    assert_eq!(cols_idx.len(), w.n_values(), "col index cache length");
    let vpr = w.values_per_row();
    // matmul_bt folds column j into accumulator j % 4 for j < chunks4 and
    // into the scalar tail after; reproduce that assignment exactly.
    let chunks4 = (k / 4) * 4;
    let dd = delta.data();
    let vals = &w.values[..];
    let od = out.data_mut();
    let disp = Dispatch::active();
    let tile = disp.tile();
    let mut b0 = 0usize;
    if batch >= tile {
        // Scratch growth before the tile loop — steady state allocates
        // nothing.
        let dt = grown(&mut scratch.dt, k * tile);
        let acc = grown(&mut scratch.acc, 5 * tile);
        while b0 + tile <= batch {
            // Transpose the delta panel: dt[j][t] = delta[b0 + t][j].
            for t in 0..tile {
                let drow = &dd[(b0 + t) * k..(b0 + t + 1) * k];
                for (j, &v) in drow.iter().enumerate() {
                    dt[j * tile + t] = v;
                }
            }
            for i in 0..rows {
                let s = i * vpr;
                acc.fill(0.0);
                for (&v, &j) in vals[s..s + vpr].iter().zip(&cols_idx[s..s + vpr]) {
                    let j = j as usize;
                    let part = if j < chunks4 { j & 3 } else { 4 };
                    disp.axpy(&mut acc[part * tile..(part + 1) * tile], &dt[j * tile..(j + 1) * tile], v);
                }
                for t in 0..tile {
                    od[(b0 + t) * rows + i] =
                        acc[t] + acc[tile + t] + acc[2 * tile + t] + acc[3 * tile + t] + acc[4 * tile + t];
                }
            }
            b0 += tile;
        }
    }
    for b in b0..batch {
        let drow = &dd[b * k..(b + 1) * k];
        let orow = &mut od[b * rows..(b + 1) * rows];
        for (i, o) in orow.iter_mut().enumerate() {
            let s = i * vpr;
            let mut acc = [0.0f32; 4];
            let mut tail = 0.0f32;
            for (&v, &j) in vals[s..s + vpr].iter().zip(&cols_idx[s..s + vpr]) {
                let j = j as usize;
                let p = drow[j] * v;
                if j < chunks4 {
                    acc[j & 3] += p;
                } else {
                    tail += p;
                }
            }
            *o = acc[0] + acc[1] + acc[2] + acc[3] + tail;
        }
    }
}

/// One parameter's gradient from
/// [`Mlp::loss_and_grad_packed`](crate::model::Mlp::loss_and_grad_packed):
/// dense tensors get dense gradients, packed weights get **compact**
/// gradients aligned with [`PackedNmTensor::values`] storage order — the
/// pruned coordinates are never materialized.
#[derive(Debug, Clone, PartialEq)]
pub enum PackedGrad {
    /// Gradient of a dense parameter (bias / final layer / dense weight).
    Dense(Tensor),
    /// Compact gradient of a packed weight (kept slots only, storage order).
    Compact(Vec<f32>),
}

impl PackedGrad {
    /// The dense gradient, if this parameter is dense.
    pub fn as_dense(&self) -> Option<&Tensor> {
        match self {
            PackedGrad::Dense(t) => Some(t),
            PackedGrad::Compact(_) => None,
        }
    }

    /// The compact gradient, if this parameter is packed.
    pub fn as_compact(&self) -> Option<&[f32]> {
        match self {
            PackedGrad::Dense(_) => None,
            PackedGrad::Compact(v) => Some(v),
        }
    }

    /// Stored scalar count (kept slots only for compact gradients).
    pub fn len(&self) -> usize {
        match self {
            PackedGrad::Dense(t) => t.numel(),
            PackedGrad::Compact(v) => v.len(),
        }
    }

    /// True when no scalars are stored.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

// ---------------------------------------------------------------------------
// packed parameter lists (whole-model export)
// ---------------------------------------------------------------------------

/// One parameter of a packed model: sparse-eligible weights are stored
/// compressed, everything else (biases, final layer) stays dense.
#[derive(Debug, Clone)]
pub enum PackedParam {
    /// A dense tensor (bias / final layer / dense-ratio weight).
    Dense(Tensor),
    /// A compressed N:M weight.
    Packed(PackedNmTensor),
}

impl PackedParam {
    /// Logical (dense) shape.
    pub fn shape(&self) -> &[usize] {
        match self {
            PackedParam::Dense(t) => t.shape(),
            PackedParam::Packed(p) => p.shape(),
        }
    }

    /// The dense tensor, if this parameter is stored dense.
    pub fn as_dense(&self) -> Option<&Tensor> {
        match self {
            PackedParam::Dense(t) => Some(t),
            PackedParam::Packed(_) => None,
        }
    }

    /// The packed tensor, if this parameter is stored compressed.
    pub fn as_packed(&self) -> Option<&PackedNmTensor> {
        match self {
            PackedParam::Dense(_) => None,
            PackedParam::Packed(p) => Some(p),
        }
    }

    /// Materialize the dense (masked) form.
    pub fn unpack(&self) -> Tensor {
        match self {
            PackedParam::Dense(t) => t.clone(),
            PackedParam::Packed(p) => p.unpack(),
        }
    }

    /// Stored payload bytes (compressed for packed entries).
    pub fn stored_bytes(&self) -> usize {
        match self {
            PackedParam::Dense(t) => t.numel() * 4,
            PackedParam::Packed(p) => p.packed_bytes(),
        }
    }

    /// Payload bytes of the dense form.
    pub fn dense_bytes(&self) -> usize {
        match self {
            PackedParam::Dense(t) => t.numel() * 4,
            PackedParam::Packed(p) => p.dense_bytes(),
        }
    }
}

/// Pack a parameter list: tensors with a (non-dense) ratio are compressed,
/// the rest are cloned dense — the export step a trained
/// [`crate::optim::RecipeState`] or [`crate::coordinator::Session`] runs
/// once at the end of training ("pack at phase-2 exit").
pub fn pack_params(params: &[Tensor], ratios: &[Option<NmRatio>]) -> Vec<PackedParam> {
    assert_eq!(params.len(), ratios.len(), "params/ratios arity mismatch");
    params
        .iter()
        .zip(ratios)
        .map(|(p, r)| match r {
            Some(r) if !r.is_dense() => PackedParam::Packed(PackedNmTensor::pack(p, *r)),
            _ => PackedParam::Dense(p.clone()),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::sparsity::{apply_nm, nm_mask};
    use crate::tensor::{matmul, Tensor};
    use crate::testutil::{gen_nm, gen_shape_div_m, gen_tensor, gen_tensor_with_ties, Cases};

    #[test]
    fn pack_unpack_roundtrip_2_4() {
        let w = Tensor::new(&[1, 8], vec![0.1, -3.0, 2.0, 0.5, 1.0, -1.0, 0.2, 0.0]);
        let p = PackedNmTensor::pack(&w, NmRatio::new(2, 4));
        assert_eq!(p.n_values(), 4);
        assert_eq!(p.unpack(), apply_nm(&w, NmRatio::new(2, 4)));
    }

    #[test]
    fn property_roundtrip_matches_apply_nm() {
        Cases::new(120).run(|rng, _| {
            let (n, m) = gen_nm(rng);
            let (r, c) = gen_shape_div_m(rng, m, 6, 6);
            let w = gen_tensor_with_ties(rng, &[r, c]);
            let ratio = NmRatio::new(n, m);
            let p = PackedNmTensor::pack(&w, ratio);
            assert_eq!(p.unpack(), apply_nm(&w, ratio), "{n}:{m} shape ({r},{c})");
            assert_eq!(p.n_values(), r * c / m * n);
        });
    }

    #[test]
    fn tail_groups_are_stored_dense() {
        // cols = 10 at M=4: two full groups + a 2-wide dense tail per row
        let mut rng = Pcg64::new(3);
        let w = Tensor::randn(&[3, 10], &mut rng, 0.0, 1.0);
        let ratio = NmRatio::new(1, 4);
        let p = PackedNmTensor::pack(&w, ratio);
        assert_eq!(p.n_values(), 3 * (2 * 1 + 2));
        let back = p.unpack();
        for r in 0..3 {
            for g in 0..2 {
                // full groups: selection identical to nm_mask on the group
                let group: Vec<f32> =
                    w.data()[r * 10 + g * 4..r * 10 + g * 4 + 4].to_vec();
                let mask = nm_mask(&Tensor::new(&[1, 4], group.clone()), ratio);
                for j in 0..4 {
                    let expect = if mask.data()[j] != 0.0 { group[j] } else { 0.0 };
                    assert_eq!(back.data()[r * 10 + g * 4 + j], expect);
                }
            }
            // tail: kept verbatim
            assert_eq!(&back.data()[r * 10 + 8..r * 10 + 10], &w.data()[r * 10 + 8..r * 10 + 10]);
        }
    }

    #[test]
    fn nonfinite_kept_values_survive_bit_exactly() {
        let w = Tensor::new(
            &[2, 4],
            vec![f32::NAN, 1.0, f32::INFINITY, 0.5, f32::NEG_INFINITY, -0.0, f32::NAN, 3.0],
        );
        let ratio = NmRatio::new(2, 4);
        let p = PackedNmTensor::pack(&w, ratio);
        let back = p.unpack();
        let expect = apply_nm(&w, ratio);
        for i in 0..w.numel() {
            let (a, b) = (back.data()[i], expect.data()[i]);
            assert_eq!(a.to_bits(), b.to_bits(), "slot {i}: {a} vs {b}");
        }
    }

    #[test]
    fn from_parts_validates() {
        let mut rng = Pcg64::new(5);
        let w = Tensor::randn(&[4, 8], &mut rng, 0.0, 1.0);
        let p = PackedNmTensor::pack(&w, NmRatio::new(2, 4));
        let ok = PackedNmTensor::from_parts(
            p.shape().to_vec(),
            p.ratio(),
            p.values().to_vec(),
            p.codes().to_vec(),
        )
        .unwrap();
        assert_eq!(ok, p);
        // wrong value count
        assert!(PackedNmTensor::from_parts(
            p.shape().to_vec(),
            p.ratio(),
            vec![0.0; 3],
            p.codes().to_vec(),
        )
        .is_err());
        // corrupt code population (a group keeping 3 of 4)
        let mut bad = p.codes().to_vec();
        bad[0] |= 0x0F;
        assert!(PackedNmTensor::from_parts(
            p.shape().to_vec(),
            p.ratio(),
            p.values().to_vec(),
            bad,
        )
        .is_err());
    }

    #[test]
    fn matvec_matches_dense_masked_bitwise() {
        Cases::new(60).run(|rng, _| {
            let (n, m) = gen_nm(rng);
            let (k, c) = gen_shape_div_m(rng, m, 8, 6);
            let w = gen_tensor(rng, &[k, c]);
            let ratio = NmRatio::new(n, m);
            let masked = apply_nm(&w, ratio);
            let p = PackedNmTensor::pack(&w, ratio);
            // x with exact zeros sprinkled in (the ReLU-activation case)
            let mut x = gen_tensor(rng, &[1, k]);
            for v in x.data_mut().iter_mut() {
                if rng.below(3) == 0 {
                    *v = 0.0;
                }
            }
            let dense = matmul(&x, &masked);
            let mut y = vec![0f32; c];
            packed_matvec(x.data(), &p, &mut y);
            assert_eq!(dense.data(), &y[..], "{n}:{m} ({k},{c})");
        });
    }

    #[test]
    fn batched_matmul_matches_dense_masked_bitwise() {
        // batches chosen to exercise: pure-matvec (<8), exact tiles, and
        // tiles + remainder
        Cases::new(25).run(|rng, case| {
            let (n, m) = gen_nm(rng);
            let (k, c) = gen_shape_div_m(rng, m, 6, 5);
            let w = gen_tensor(rng, &[k, c]);
            let ratio = NmRatio::new(n, m);
            let masked = apply_nm(&w, ratio);
            let p = PackedNmTensor::pack(&w, ratio);
            let batch = [1, 3, 8, 16, 19, 37][case % 6];
            let mut h = gen_tensor(rng, &[batch, k]);
            for v in h.data_mut().iter_mut() {
                if rng.below(3) == 0 {
                    *v = 0.0;
                }
            }
            let dense = matmul(&h, &masked);
            let sparse = packed_matmul(&h, &p);
            assert_eq!(dense, sparse, "{n}:{m} batch {batch}");
        });
    }

    #[test]
    fn matmul_with_tail_matches_per_row_matvec() {
        let mut rng = Pcg64::new(11);
        let w = Tensor::randn(&[6, 11], &mut rng, 0.0, 1.0);
        let p = PackedNmTensor::pack(&w, NmRatio::new(2, 4));
        let h = Tensor::randn(&[13, 6], &mut rng, 0.0, 1.0);
        let out = packed_matmul(&h, &p);
        for b in 0..13 {
            let mut y = vec![0f32; 11];
            packed_matvec(&h.data()[b * 6..(b + 1) * 6], &p, &mut y);
            assert_eq!(&out.data()[b * 11..(b + 1) * 11], &y[..], "row {b}");
        }
        // and the unpacked form agrees with a dense product
        let dense = matmul(&h, &p.unpack());
        assert_eq!(dense, out);
    }

    #[test]
    fn compression_accounting_2_4() {
        let w = Tensor::zeros(&[64, 64]);
        let p = PackedNmTensor::pack(&w, NmRatio::new(2, 4));
        // 2 f32 values + 4 code bits per group of 4 → 8.5 / 16 bytes
        assert_eq!(p.packed_bytes(), 64 * 16 * 8 + 64 * 16 / 2);
        assert!((p.compression() - 8.5 / 16.0).abs() < 1e-12);
    }

    #[test]
    fn pack_params_mixes_dense_and_packed() {
        let mut rng = Pcg64::new(9);
        let params = vec![
            Tensor::randn(&[8, 16], &mut rng, 0.0, 1.0),
            Tensor::randn(&[16], &mut rng, 0.0, 1.0),
        ];
        let ratios = vec![Some(NmRatio::new(2, 4)), None];
        let packed = pack_params(&params, &ratios);
        assert!(packed[0].as_packed().is_some());
        assert!(packed[1].as_dense().is_some());
        assert_eq!(packed[0].unpack(), apply_nm(&params[0], NmRatio::new(2, 4)));
        assert_eq!(packed[1].unpack(), params[1]);
        assert!(packed[0].stored_bytes() < packed[0].dense_bytes());
    }

    #[test]
    #[should_panic]
    fn pack_rejects_oversized_m() {
        let w = Tensor::zeros(&[1, 64]);
        PackedNmTensor::pack(&w, NmRatio::new(1, 64));
    }

    #[test]
    fn col_indices_agree_with_unpack_support() {
        Cases::new(40).run(|rng, _| {
            let (n, m) = gen_nm(rng);
            let (r, c) = gen_shape_div_m(rng, m, 5, 5);
            let w = gen_tensor_with_ties(rng, &[r, c]);
            let p = PackedNmTensor::pack(&w, NmRatio::new(n, m));
            let cols_idx = p.col_indices();
            assert_eq!(cols_idx.len(), p.n_values());
            let vpr = p.values_per_row();
            assert_eq!(vpr * r, p.n_values());
            // scattering values at the decoded indices reproduces unpack()
            let back = p.unpack();
            let mut scattered = Tensor::zeros(&[r, c]);
            for (vc, &j) in cols_idx.iter().enumerate() {
                let row = vc / vpr;
                scattered.data_mut()[row * c + j as usize] = p.values()[vc];
            }
            assert_eq!(scattered, back, "{n}:{m} ({r},{c})");
        });
    }

    #[test]
    fn col_indices_cover_dense_tails() {
        let mut rng = Pcg64::new(19);
        let w = Tensor::randn(&[2, 11], &mut rng, 0.0, 1.0);
        let p = PackedNmTensor::pack(&w, NmRatio::new(2, 4));
        let vpr = p.values_per_row();
        assert_eq!(vpr, 2 * 2 + 3); // two full groups kept 2 each + 3 tail
        let cols_idx = p.col_indices();
        // tail indices 8, 9, 10 appear verbatim at the end of each row
        for r in 0..2 {
            assert_eq!(&cols_idx[r * vpr + 4..(r + 1) * vpr], &[8, 9, 10]);
        }
    }

    #[test]
    fn compact_like_gathers_kept_coordinates() {
        let mut rng = Pcg64::new(23);
        let w = Tensor::randn(&[4, 8], &mut rng, 0.0, 1.0);
        let p = PackedNmTensor::pack(&w, NmRatio::new(2, 4));
        // compacting the source itself returns the stored values verbatim
        assert_eq!(p.compact_like(&w), p.values());
        // compacting an unrelated tensor gathers at the same support
        let other = Tensor::randn(&[4, 8], &mut rng, 0.0, 1.0);
        let compact = p.compact_like(&other);
        let mask = nm_mask(&w, NmRatio::new(2, 4));
        let gathered: Vec<f32> = other
            .data()
            .iter()
            .zip(mask.data())
            .filter(|(_, &k)| k != 0.0)
            .map(|(&x, _)| x)
            .collect();
        assert_eq!(compact, gathered);
    }

    #[test]
    fn packed_matmul_at_matches_dense_on_kept_coordinates() {
        Cases::new(50).run(|rng, case| {
            let (n, m) = gen_nm(rng);
            let (k, c) = gen_shape_div_m(rng, m, 6, 5);
            let w = gen_tensor_with_ties(rng, &[k, c]);
            let ratio = NmRatio::new(n, m);
            let p = PackedNmTensor::pack(&w, ratio);
            let batch = [1usize, 3, 8, 17][case % 4];
            // activations with exact zeros (the post-ReLU case)
            let mut a = gen_tensor(rng, &[batch, k]);
            for v in a.data_mut().iter_mut() {
                if rng.below(3) == 0 {
                    *v = 0.0;
                }
            }
            let delta = gen_tensor(rng, &[batch, c]);
            let dense = crate::tensor::matmul_at(&a, &delta);
            let compact = packed_matmul_at(&a, &delta, &p);
            let vpr = p.values_per_row();
            for (vc, &j) in p.col_indices().iter().enumerate() {
                let row = vc / vpr;
                let d = dense.data()[row * c + j as usize];
                assert_eq!(
                    d.to_bits(),
                    compact[vc].to_bits(),
                    "{n}:{m} batch {batch} value {vc}: {d} vs {}",
                    compact[vc]
                );
            }
        });
    }

    #[test]
    fn packed_matmul_bt_matches_dense_masked_bitwise() {
        Cases::new(50).run(|rng, case| {
            let (n, m) = gen_nm(rng);
            let (k, c) = gen_shape_div_m(rng, m, 6, 5);
            let w = gen_tensor_with_ties(rng, &[k, c]);
            let ratio = NmRatio::new(n, m);
            let masked = apply_nm(&w, ratio);
            let p = PackedNmTensor::pack(&w, ratio);
            let batch = [1usize, 2, 9, 16][case % 4];
            let delta = gen_tensor(rng, &[batch, c]);
            let dense = crate::tensor::matmul_bt(&delta, &masked);
            let sparse = packed_matmul_bt(&delta, &p);
            assert_eq!(dense.shape(), sparse.shape());
            for i in 0..dense.numel() {
                assert_eq!(
                    dense.data()[i].to_bits(),
                    sparse.data()[i].to_bits(),
                    "{n}:{m} batch {batch} slot {i}: {} vs {}",
                    dense.data()[i],
                    sparse.data()[i]
                );
            }
        });
    }

    #[test]
    fn backward_kernels_handle_tails() {
        let mut rng = Pcg64::new(31);
        let w = Tensor::randn(&[6, 11], &mut rng, 0.0, 1.0);
        let ratio = NmRatio::new(2, 4);
        let p = PackedNmTensor::pack(&w, ratio);
        let masked = apply_nm(&w, ratio);
        let a = Tensor::randn(&[5, 6], &mut rng, 0.0, 1.0);
        let delta = Tensor::randn(&[5, 11], &mut rng, 0.0, 1.0);
        // bt over the tail-carrying shape
        let dense_bt = matmul(&delta, &{
            // build maskedᵀ by hand for a reference-free check
            let mut t = Tensor::zeros(&[11, 6]);
            for i in 0..6 {
                for j in 0..11 {
                    t.set(&[j, i], masked.get(&[i, j]));
                }
            }
            t
        });
        let sparse_bt = packed_matmul_bt(&delta, &p);
        // numerically equal (exact bit-equality is vs matmul_bt, checked
        // above; this guards the tail indexing against a plain transpose)
        for i in 0..dense_bt.numel() {
            let (x, y) = (dense_bt.data()[i], sparse_bt.data()[i]);
            assert!((x - y).abs() <= 1e-4 * (1.0 + x.abs()), "slot {i}: {x} vs {y}");
        }
        // at over the tail-carrying shape: kept coordinates match dense
        let dense_at = crate::tensor::matmul_at(&a, &delta);
        let compact = packed_matmul_at(&a, &delta, &p);
        let vpr = p.values_per_row();
        for (vc, &j) in p.col_indices().iter().enumerate() {
            let row = vc / vpr;
            assert_eq!(
                dense_at.data()[row * 11 + j as usize].to_bits(),
                compact[vc].to_bits(),
                "value {vc}"
            );
        }
    }
}
