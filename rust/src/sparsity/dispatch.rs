//! Runtime SIMD dispatch for the packed kernel family.
//!
//! The packed kernels ([`super::packed`]) and the attention models batch
//! their inner loops over *independent accumulators* — batch lanes in the
//! row-tiled forward, score columns against a transposed key panel, output
//! elements of a context row. Each accumulator still receives exactly the
//! terms the dense masked oracle gave it, in the same ascending order; the
//! vector unit only evaluates several such independent chains per
//! instruction. That is the repo's bit-identity contract: **speedups come
//! from vectorization, never reassociation.**
//!
//! [`Dispatch`] is the one-time CPU-feature decision behind that strategy:
//!
//! * `x86_64` — AVX2 when the CPU reports it (checked once through
//!   `is_x86_feature_detected!`), otherwise the SSE2 baseline every
//!   `x86_64` target guarantees;
//! * `aarch64` — NEON, mandatory on the architecture;
//! * anything else — the scalar reference loops.
//!
//! `NM_FORCE_SCALAR=1` (or [`Dispatch::scalar`]) forces the scalar tier so
//! both paths stay testable on any machine; [`Dispatch::candidates`]
//! enumerates every tier the current machine can run, which is how the
//! property tests pin SIMD against scalar bit-for-bit.
//!
//! The per-element kernel is [`Dispatch::axpy`]: `acc[t] += a * x[t]`.
//! Per lane this is one IEEE-754 single multiply and one add — bitwise
//! identical to the scalar statement (Rust never enables FTZ, and the
//! intrinsics used here are the exact-rounding `mul`/`add` pairs, never
//! FMA, so there is no double-rounding difference). All `unsafe` intrinsic
//! use in the crate is confined to this module and enforced by nm-lint's
//! `unsafe-confinement` rule.

use std::sync::OnceLock;

/// The instruction tiers this build can name. Only tiers valid for the
/// compilation target exist, and `Avx2` is only ever constructed after a
/// positive runtime feature check — which is what makes the safe
/// [`Dispatch::axpy`] wrapper sound.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Tier {
    Scalar,
    #[cfg(target_arch = "x86_64")]
    Sse2,
    #[cfg(target_arch = "x86_64")]
    Avx2,
    #[cfg(target_arch = "aarch64")]
    Neon,
}

/// A validated SIMD tier. Copy-cheap; pass it by value into kernels.
///
/// The inner [`Tier`] is private on purpose: the only constructors are
/// [`Dispatch::scalar`], [`Dispatch::detect`], [`Dispatch::active`] and
/// [`Dispatch::candidates`], each of which guarantees the tier is actually
/// runnable on this machine. That invariant is what lets [`Dispatch::axpy`]
/// call `#[target_feature]` code from a safe API.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Dispatch(Tier);

/// Cached process-wide dispatch decision (one feature probe per process).
static ACTIVE: OnceLock<Dispatch> = OnceLock::new();

impl Dispatch {
    /// The scalar reference tier — always available, on every arch.
    pub fn scalar() -> Self {
        Dispatch(Tier::Scalar)
    }

    /// Probe the CPU and return the widest tier it supports.
    pub fn detect() -> Self {
        Dispatch(detect_tier())
    }

    /// The tier every kernel in the process uses: [`Dispatch::detect`]
    /// once, cached — unless `NM_FORCE_SCALAR` is set to a non-empty value
    /// other than `0`, which pins the whole process to the scalar tier.
    pub fn active() -> Self {
        *ACTIVE.get_or_init(|| {
            if force_scalar_env() {
                Dispatch(Tier::Scalar)
            } else {
                Dispatch::detect()
            }
        })
    }

    /// Every tier that can run on this machine, scalar first. The property
    /// tests iterate this to pin each SIMD tier against the scalar oracle.
    pub fn candidates() -> Vec<Dispatch> {
        let mut out = vec![Dispatch(Tier::Scalar)];
        #[cfg(target_arch = "x86_64")]
        {
            out.push(Dispatch(Tier::Sse2));
            if is_x86_feature_detected!("avx2") {
                out.push(Dispatch(Tier::Avx2));
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            out.push(Dispatch(Tier::Neon));
        }
        out
    }

    /// Stable lower-case tier name — recorded in every `BENCH_*.json` so
    /// perf trajectories are comparable across machines.
    pub fn name(self) -> &'static str {
        match self.0 {
            Tier::Scalar => "scalar",
            #[cfg(target_arch = "x86_64")]
            Tier::Sse2 => "sse2",
            #[cfg(target_arch = "x86_64")]
            Tier::Avx2 => "avx2",
            #[cfg(target_arch = "aarch64")]
            Tier::Neon => "neon",
        }
    }

    /// f32 lanes per vector register on this tier.
    pub fn lanes(self) -> usize {
        match self.0 {
            Tier::Scalar => 1,
            #[cfg(target_arch = "x86_64")]
            Tier::Sse2 => 4,
            #[cfg(target_arch = "x86_64")]
            Tier::Avx2 => 8,
            #[cfg(target_arch = "aarch64")]
            Tier::Neon => 4,
        }
    }

    /// Row-tile width for the batch-tiled packed kernels: two vector
    /// registers of accumulators per streamed weight. The scalar tier keeps
    /// the legacy width 8 so a forced-scalar run walks the exact tiling the
    /// seed kernels used.
    pub fn tile(self) -> usize {
        match self.0 {
            Tier::Scalar => 8,
            #[cfg(target_arch = "x86_64")]
            Tier::Sse2 => 8,
            #[cfg(target_arch = "x86_64")]
            Tier::Avx2 => 16,
            #[cfg(target_arch = "aarch64")]
            Tier::Neon => 8,
        }
    }

    /// The dispatch primitive: `acc[t] += a * x[t]` over
    /// `min(acc.len(), x.len())` elements.
    ///
    /// Every tier performs, per element, one IEEE single-precision multiply
    /// followed by one add — the SIMD tiers evaluate 4 or 8 independent
    /// elements per instruction but each lane rounds exactly like the
    /// scalar statement. No FMA, no reordering across elements.
    #[inline]
    pub fn axpy(self, acc: &mut [f32], x: &[f32], a: f32) {
        match self.0 {
            Tier::Scalar => axpy_scalar(acc, x, a),
            #[cfg(target_arch = "x86_64")]
            // SAFETY: SSE2 is part of the x86_64 baseline ABI.
            Tier::Sse2 => unsafe { axpy_sse2(acc, x, a) },
            #[cfg(target_arch = "x86_64")]
            // SAFETY: Tier::Avx2 is only constructed after
            // `is_x86_feature_detected!("avx2")` returned true.
            Tier::Avx2 => unsafe { axpy_avx2(acc, x, a) },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: NEON is mandatory on aarch64 targets.
            Tier::Neon => unsafe { axpy_neon(acc, x, a) },
        }
    }
}

/// `NM_FORCE_SCALAR` set to anything non-empty other than `0`?
fn force_scalar_env() -> bool {
    match std::env::var("NM_FORCE_SCALAR") {
        Ok(v) => !v.is_empty() && v != "0",
        Err(_) => false,
    }
}

#[cfg(target_arch = "x86_64")]
fn detect_tier() -> Tier {
    if is_x86_feature_detected!("avx2") {
        Tier::Avx2
    } else {
        Tier::Sse2
    }
}

#[cfg(target_arch = "aarch64")]
fn detect_tier() -> Tier {
    Tier::Neon
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn detect_tier() -> Tier {
    Tier::Scalar
}

// ---------------------------------------------------------------------------
// per-tier axpy implementations
// ---------------------------------------------------------------------------

#[inline]
fn axpy_scalar(acc: &mut [f32], x: &[f32], a: f32) {
    let n = if acc.len() < x.len() { acc.len() } else { x.len() };
    for t in 0..n {
        acc[t] += a * x[t];
    }
}

/// SSE2 axpy — 4 lanes. Always callable on `x86_64` (baseline ISA).
///
/// # Safety
/// Raw-pointer loads/stores; bounds are established by `t + 4 <= n` with
/// `n` clamped to both slice lengths. `loadu`/`storeu` are alignment-free.
#[cfg(target_arch = "x86_64")]
#[inline]
unsafe fn axpy_sse2(acc: &mut [f32], x: &[f32], a: f32) {
    use core::arch::x86_64::{_mm_add_ps, _mm_loadu_ps, _mm_mul_ps, _mm_set1_ps, _mm_storeu_ps};
    let n = if acc.len() < x.len() { acc.len() } else { x.len() };
    let av = _mm_set1_ps(a);
    let mut t = 0usize;
    while t + 4 <= n {
        // split mul + add, never FMA: each lane must round exactly like
        // the scalar `acc[t] + a * x[t]`
        let prod = _mm_mul_ps(av, _mm_loadu_ps(x.as_ptr().add(t)));
        let sum = _mm_add_ps(_mm_loadu_ps(acc.as_ptr().add(t)), prod);
        _mm_storeu_ps(acc.as_mut_ptr().add(t), sum);
        t += 4;
    }
    while t < n {
        acc[t] += a * x[t];
        t += 1;
    }
}

/// AVX2 axpy — 8 lanes.
///
/// # Safety
/// Caller must have verified AVX2 support (`Tier::Avx2` construction does);
/// pointer bounds as in [`axpy_sse2`].
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[inline]
unsafe fn axpy_avx2(acc: &mut [f32], x: &[f32], a: f32) {
    use core::arch::x86_64::{
        _mm256_add_ps, _mm256_loadu_ps, _mm256_mul_ps, _mm256_set1_ps, _mm256_storeu_ps,
    };
    let n = if acc.len() < x.len() { acc.len() } else { x.len() };
    let av = _mm256_set1_ps(a);
    let mut t = 0usize;
    while t + 8 <= n {
        let prod = _mm256_mul_ps(av, _mm256_loadu_ps(x.as_ptr().add(t)));
        let sum = _mm256_add_ps(_mm256_loadu_ps(acc.as_ptr().add(t)), prod);
        _mm256_storeu_ps(acc.as_mut_ptr().add(t), sum);
        t += 8;
    }
    while t < n {
        acc[t] += a * x[t];
        t += 1;
    }
}

/// NEON axpy — 4 lanes. NEON is mandatory on aarch64.
///
/// # Safety
/// Pointer bounds as in [`axpy_sse2`].
#[cfg(target_arch = "aarch64")]
#[inline]
unsafe fn axpy_neon(acc: &mut [f32], x: &[f32], a: f32) {
    use core::arch::aarch64::{vaddq_f32, vdupq_n_f32, vld1q_f32, vmulq_f32, vst1q_f32};
    let n = if acc.len() < x.len() { acc.len() } else { x.len() };
    let av = vdupq_n_f32(a);
    let mut t = 0usize;
    while t + 4 <= n {
        let prod = vmulq_f32(av, vld1q_f32(x.as_ptr().add(t)));
        let sum = vaddq_f32(vld1q_f32(acc.as_ptr().add(t)), prod);
        vst1q_f32(acc.as_mut_ptr().add(t), sum);
        t += 4;
    }
    while t < n {
        acc[t] += a * x[t];
        t += 1;
    }
}

// ---------------------------------------------------------------------------
// batched attention helpers — one call covers every head of a sequence row
// ---------------------------------------------------------------------------

/// Attention scores for **all heads** of one query row in a single call,
/// against a transposed key panel.
///
/// * `q` — the query row, `heads * dh` long (head `h` at `q[h*dh..][..dh]`);
/// * `kt` — transposed keys: component `c` of key `j` at `kt[c*kt_stride + j]`;
/// * `kv` — number of key positions to score (`<= kt_stride`);
/// * `out` — head `h`'s score row is `out[h*out_stride..][..kv]`; it is
///   overwritten (zero-filled, accumulated, then scaled).
///
/// Bit-identity: score `j` of head `h` starts at `0.0` and receives
/// `q[h*dh+t] * k_j[h*dh+t]` for `t` ascending — exactly the scalar dot
/// loop's term sequence — then one multiply by `scale`. The SIMD tier only
/// advances independent `j` columns in lock-step.
#[allow(clippy::too_many_arguments)]
pub fn attn_scores_all_heads(
    d: Dispatch,
    q: &[f32],
    kt: &[f32],
    kt_stride: usize,
    kv: usize,
    dh: usize,
    scale: f32,
    out: &mut [f32],
    out_stride: usize,
) {
    let heads = q.len() / dh;
    for h in 0..heads {
        let orow = &mut out[h * out_stride..][..kv];
        orow.fill(0.0);
        for t in 0..dh {
            let c = h * dh + t;
            d.axpy(orow, &kt[c * kt_stride..][..kv], q[c]);
        }
        for s in orow.iter_mut() {
            *s *= scale;
        }
    }
}

/// Attention scores for **all heads** of one query row against *row-major*
/// keys (the KV-cache layout, where transposing would cost as much as the
/// dot products themselves). Scalar ascending-`t` dots — one call still
/// covers every head, and the term order matches [`attn_scores_all_heads`]
/// exactly, so cached decode stays bit-identical to the full forward pass.
///
/// Key `j` lives at `kr[j*k_stride..][..heads*dh]`; head `h`'s score row is
/// `out[h*out_stride..][..kv]`.
#[allow(clippy::too_many_arguments)]
pub fn attn_scores_rows_all_heads(
    q: &[f32],
    kr: &[f32],
    k_stride: usize,
    kv: usize,
    dh: usize,
    scale: f32,
    out: &mut [f32],
    out_stride: usize,
) {
    let heads = q.len() / dh;
    for h in 0..heads {
        let qrow = &q[h * dh..][..dh];
        let orow = &mut out[h * out_stride..][..kv];
        for (j, o) in orow.iter_mut().enumerate() {
            let krow = &kr[j * k_stride + h * dh..][..dh];
            let mut acc = 0f32;
            for t in 0..dh {
                acc += qrow[t] * krow[t];
            }
            *o = acc * scale;
        }
    }
}

/// Probability-weighted value accumulation for **all heads** of one output
/// row: `out[h*dh + t] += probs[h*p_stride + j] * v_j[h*dh + t]` for `j`
/// ascending. `out` must be zeroed on entry (`heads * dh` long); value row
/// `j` lives at `v[j*v_stride..][..heads*dh]`.
///
/// Bit-identity: every output element accumulates its probability-weighted
/// value terms for `j` strictly ascending — the scalar per-head loop's
/// order — the SIMD tier only advances the `dh` elements of a head in
/// lock-step.
pub fn attn_context_all_heads(
    d: Dispatch,
    probs: &[f32],
    p_stride: usize,
    kv: usize,
    v: &[f32],
    v_stride: usize,
    dh: usize,
    out: &mut [f32],
) {
    let heads = out.len() / dh;
    for j in 0..kv {
        let vrow = &v[j * v_stride..][..out.len()];
        for h in 0..heads {
            d.axpy(&mut out[h * dh..][..dh], &vrow[h * dh..][..dh], probs[h * p_stride + j]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::Cases;

    fn bits_eq(a: f32, b: f32) -> bool {
        a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan())
    }

    #[test]
    fn detect_and_active_are_runnable_candidates() {
        let cands = Dispatch::candidates();
        assert_eq!(cands[0], Dispatch::scalar());
        assert!(cands.contains(&Dispatch::detect()));
        assert!(cands.contains(&Dispatch::active()));
        for d in cands {
            assert!(d.lanes() >= 1);
            assert!(d.tile() >= d.lanes());
            assert!(!d.name().is_empty());
        }
    }

    #[test]
    fn scalar_tier_is_stable() {
        let d = Dispatch::scalar();
        assert_eq!(d.name(), "scalar");
        assert_eq!(d.lanes(), 1);
        assert_eq!(d.tile(), 8, "forced-scalar must keep the legacy tile width");
    }

    #[test]
    fn axpy_matches_scalar_bitwise_on_every_tier() {
        Cases::new(64).run(|rng, _| {
            let n = rng.range(1, 70); // crosses 4- and 8-lane boundaries + tails
            let a = (rng.f32() - 0.5) * 4.0;
            let x: Vec<f32> = (0..n).map(|_| (rng.f32() - 0.5) * 2.0).collect();
            let base: Vec<f32> = (0..n).map(|_| (rng.f32() - 0.5) * 2.0).collect();
            let mut want = base.clone();
            axpy_scalar(&mut want, &x, a);
            for d in Dispatch::candidates() {
                let mut got = base.clone();
                d.axpy(&mut got, &x, a);
                for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
                    assert!(bits_eq(g, w), "{}: lane {i}: {g:?} vs {w:?}", d.name());
                }
            }
        });
    }

    #[test]
    fn axpy_handles_nan_and_inf_payloads() {
        let specials = [f32::NAN, f32::INFINITY, f32::NEG_INFINITY, -0.0, 1.5e38];
        for (si, &s) in specials.iter().enumerate() {
            let mut x = vec![1.0f32; 19];
            x[si % 19] = s;
            x[18] = -s;
            let base = vec![0.25f32; 19];
            let mut want = base.clone();
            axpy_scalar(&mut want, &x, 2.0);
            for d in Dispatch::candidates() {
                let mut got = base.clone();
                d.axpy(&mut got, &x, 2.0);
                for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
                    assert!(bits_eq(g, w), "{}: lane {i}: {g:?} vs {w:?}", d.name());
                }
            }
        }
    }

    #[test]
    fn scores_helper_matches_scalar_dot_loop() {
        Cases::new(32).run(|rng, _| {
            let heads = rng.range(1, 4);
            let dh = rng.range(1, 9);
            let kv = rng.range(1, 13);
            let d_model = heads * dh;
            let scale = 1.0 / (dh as f32).sqrt();
            let q: Vec<f32> = (0..d_model).map(|_| rng.f32() - 0.5).collect();
            let keys: Vec<f32> = (0..kv * d_model).map(|_| rng.f32() - 0.5).collect();
            // transposed panel: kt[c*kv + j] = keys[j*d_model + c]
            let mut kt = vec![0f32; d_model * kv];
            for j in 0..kv {
                for c in 0..d_model {
                    kt[c * kv + j] = keys[j * d_model + c];
                }
            }
            // oracle: per-head scalar dots
            let mut want = vec![0f32; heads * kv];
            for h in 0..heads {
                for j in 0..kv {
                    let mut acc = 0f32;
                    for t in 0..dh {
                        acc += q[h * dh + t] * keys[j * d_model + h * dh + t];
                    }
                    want[h * kv + j] = acc * scale;
                }
            }
            for d in Dispatch::candidates() {
                let mut got = vec![0f32; heads * kv];
                attn_scores_all_heads(d, &q, &kt, kv, kv, dh, scale, &mut got, kv);
                for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
                    assert!(bits_eq(g, w), "{}: score {i}: {g:?} vs {w:?}", d.name());
                }
            }
            // the row-major variant must agree bit-for-bit too
            let mut got = vec![0f32; heads * kv];
            attn_scores_rows_all_heads(&q, &keys, d_model, kv, dh, scale, &mut got, kv);
            for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
                assert!(bits_eq(g, w), "rows variant: score {i}: {g:?} vs {w:?}");
            }
        });
    }

    #[test]
    fn context_helper_matches_scalar_loop() {
        Cases::new(32).run(|rng, _| {
            let heads = rng.range(1, 4);
            let dh = rng.range(1, 9);
            let kv = rng.range(1, 13);
            let d_model = heads * dh;
            let probs: Vec<f32> = (0..heads * kv).map(|_| rng.f32()).collect();
            let vals: Vec<f32> = (0..kv * d_model).map(|_| rng.f32() - 0.5).collect();
            let mut want = vec![0f32; d_model];
            for h in 0..heads {
                for j in 0..kv {
                    let p = probs[h * kv + j];
                    for t in 0..dh {
                        want[h * dh + t] += p * vals[j * d_model + h * dh + t];
                    }
                }
            }
            // oracle order differs (h-outer vs j-outer) but each element's
            // term sequence is identical: j ascending.
            for d in Dispatch::candidates() {
                let mut got = vec![0f32; d_model];
                attn_context_all_heads(d, &probs, kv, kv, &vals, d_model, dh, &mut got);
                for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
                    assert!(bits_eq(g, w), "{}: ctx {i}: {g:?} vs {w:?}", d.name());
                }
            }
        });
    }
}
