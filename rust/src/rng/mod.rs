//! Deterministic random-number substrate.
//!
//! The offline image has no `rand` crate, so this module implements PCG64
//! (the PCG XSL-RR 128/64 generator) plus the distributions the experiments
//! need: uniform, standard normal (Box–Muller with cache), integer ranges,
//! Fisher–Yates permutations, categorical sampling, and a Zipf sampler for
//! the synthetic corpora.
//!
//! All experiment code takes an explicit `&mut Pcg64` so runs are exactly
//! reproducible from a seed (the sweep engine derives per-run seeds with
//! [`Pcg64::split`]).

/// PCG XSL-RR 128/64. Reference: O'Neill, "PCG: A Family of Simple Fast
/// Space-Efficient Statistically Good Algorithms for Random Number
/// Generation" (2014).
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
    /// Cached second output of the last Box–Muller draw.
    gauss_cache: Option<f64>,
}

const PCG_MULT: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Pcg64 {
    /// Seed with a stream id of 1 (any fixed odd increment works).
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 1)
    }

    /// Seed with an explicit stream; distinct streams are independent.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Self {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
            gauss_cache: None,
        };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Derive an independent child generator (for per-run / per-worker
    /// seeding). Deterministic in (self state, tag).
    pub fn split(&mut self, tag: u64) -> Pcg64 {
        let s = self.next_u64() ^ tag.rotate_left(17);
        let t = self.next_u64() ^ tag.wrapping_mul(0x9e3779b97f4a7c15);
        Pcg64::with_stream(s, t | 1)
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Uses Lemire's rejection method to avoid
    /// modulo bias.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        let threshold = n.wrapping_neg() % n;
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (n as u128);
            if (m as u64) >= threshold {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller (second value cached).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_cache.take() {
            return z;
        }
        // Avoid log(0): u in (0, 1].
        let u = 1.0 - self.f64();
        let v = self.f64();
        let r = (-2.0 * u.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * v).sin_cos();
        self.gauss_cache = Some(r * s);
        r * c
    }

    /// Normal with mean/std as f32 (the tensor dtype).
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Fill a slice with iid N(mean, std²) f32 values.
    pub fn fill_normal(&mut self, out: &mut [f32], mean: f32, std: f32) {
        for x in out.iter_mut() {
            *x = self.normal_f32(mean, std);
        }
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical with zero mass");
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Bernoulli(p).
    pub fn coin(&mut self, p: f64) -> bool {
        self.f64() < p
    }
}

/// Zipf-distributed sampler over `{0, .., n-1}` with exponent `s`
/// (rank-frequency model for the synthetic corpora). Precomputes the CDF —
/// O(log n) per sample via binary search.
#[derive(Debug, Clone)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc; // == *cdf.last(): the final accumulated mass
        for c in cdf.iter_mut() {
            *c /= total;
        }
        Self { cdf }
    }

    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        let u = rng.f64();
        match self.cdf.binary_search_by(|c| c.total_cmp(&u)) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }

    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg64::new(7);
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_smoke() {
        let mut rng = Pcg64::new(3);
        let mut counts = [0usize; 5];
        let n = 50_000;
        for _ in 0..n {
            counts[rng.below(5)] += 1;
        }
        for &c in &counts {
            let expect = n / 5;
            assert!((c as i64 - expect as i64).unsigned_abs() < (expect / 10) as u64,
                "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(11);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn permutation_is_permutation() {
        let mut rng = Pcg64::new(5);
        let p = rng.permutation(100);
        let mut seen = vec![false; 100];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
    }

    #[test]
    fn split_is_independent() {
        let mut root = Pcg64::new(9);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn zipf_is_monotone_decreasing() {
        let z = Zipf::new(50, 1.1);
        let mut rng = Pcg64::new(13);
        let mut counts = vec![0usize; 50];
        for _ in 0..200_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        // head should dominate tail
        assert!(counts[0] > counts[10] && counts[10] > counts[40]);
    }

    #[test]
    fn categorical_respects_weights() {
        let mut rng = Pcg64::new(17);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[rng.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > 2 * counts[0]);
    }
}
