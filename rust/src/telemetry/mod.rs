//! Telemetry: training traces (loss, variance stats, phase), summary
//! statistics, and CSV/JSONL sinks under `results/`.
//!
//! Figures 2, 3 and 7 are regenerated directly from these traces; the bench
//! harness writes one JSONL row per (experiment, recipe, seed) so results
//! are machine-diffable across runs.

use crate::autoswitch::SwitchStat;
use crate::util::json::{Json, JsonObj};
use std::io::Write;
use std::path::{Path, PathBuf};

/// One recorded training step.
#[derive(Debug, Clone, Copy)]
pub struct TracePoint {
    /// 1-based step.
    pub t: usize,
    pub loss: f64,
    pub stat: SwitchStat,
    /// True once the run is in the mask-learning phase.
    pub phase2: bool,
}

/// An in-memory training trace with periodic eval snapshots.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub points: Vec<TracePoint>,
    /// (step, primary eval metric) snapshots.
    pub evals: Vec<(usize, f64)>,
    /// Step at which the phase switched (0 = never).
    pub switch_step: usize,
}

impl Trace {
    pub fn push(&mut self, p: TracePoint) {
        self.points.push(p);
    }

    pub fn push_eval(&mut self, step: usize, metric: f64) {
        self.evals.push((step, metric));
    }

    /// Per-coordinate variance change `d⁻¹‖v_t − v_{t−1}‖₁` series (Fig. 3).
    pub fn z_series(&self, d: usize) -> Vec<(usize, f64)> {
        self.points
            .iter()
            .map(|p| (p.t, p.stat.dv_l1 / d as f64))
            .collect()
    }

    /// ‖v_t‖₁ series (Fig. 2).
    pub fn v_norm_series(&self) -> Vec<(usize, f64)> {
        self.points.iter().map(|p| (p.t, p.stat.v_l1)).collect()
    }

    /// Mean loss over the final `k` steps.
    pub fn tail_loss(&self, k: usize) -> f64 {
        let n = self.points.len();
        if n == 0 {
            return f64::NAN;
        }
        let start = n.saturating_sub(k);
        let slice = &self.points[start..];
        slice.iter().map(|p| p.loss).sum::<f64>() / slice.len() as f64
    }

    /// Best (max) eval metric seen.
    pub fn best_eval(&self) -> Option<(usize, f64)> {
        self.evals
            .iter()
            .copied()
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
    }

    /// Final eval metric.
    pub fn final_eval(&self) -> Option<(usize, f64)> {
        self.evals.last().copied()
    }
}

/// Summary stats over a sample (used when aggregating across seeds).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Self {
        assert!(!xs.is_empty(), "summary of empty sample");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = if n % 2 == 1 {
            sorted[n / 2]
        } else {
            0.5 * (sorted[n / 2 - 1] + sorted[n / 2])
        };
        Self {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median,
        }
    }
}

/// Append-only JSONL sink (one object per line) under `results/`.
pub struct JsonlSink {
    path: PathBuf,
}

impl JsonlSink {
    pub fn create(path: impl AsRef<Path>) -> anyhow::Result<Self> {
        let path = path.as_ref().to_path_buf();
        if let Some(dir) = path.parent() {
            crate::util::ensure_dir(dir)?;
        }
        Ok(Self { path })
    }

    pub fn append(&self, row: &JsonObj) -> anyhow::Result<()> {
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&self.path)?;
        writeln!(f, "{}", Json::Obj(row.clone()).to_string())?;
        Ok(())
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

/// Write a (step, value…) table as CSV — the plot-friendly sink for the
/// figure benches.
pub fn write_csv(
    path: impl AsRef<Path>,
    header: &[&str],
    rows: &[Vec<f64>],
) -> anyhow::Result<()> {
    let path = path.as_ref();
    if let Some(dir) = path.parent() {
        crate::util::ensure_dir(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{}", header.join(","))?;
    for row in rows {
        let line: Vec<String> = row.iter().map(|v| crate::util::fmt_sci(*v)).collect();
        writeln!(f, "{}", line.join(","))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stat(dv: f64) -> SwitchStat {
        SwitchStat { v_l1: 1.0, v_l2: 1.0, dv_l1: dv, log_dv: 0.0 }
    }

    #[test]
    fn trace_series_and_tail() {
        let mut tr = Trace::default();
        for t in 1..=10 {
            tr.push(TracePoint { t, loss: (11 - t) as f64, stat: stat(t as f64), phase2: false });
        }
        assert_eq!(tr.z_series(2)[4], (5, 2.5));
        assert_eq!(tr.v_norm_series().len(), 10);
        assert!((tr.tail_loss(2) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn trace_eval_tracking() {
        let mut tr = Trace::default();
        tr.push_eval(10, 0.5);
        tr.push_eval(20, 0.9);
        tr.push_eval(30, 0.7);
        assert_eq!(tr.best_eval(), Some((20, 0.9)));
        assert_eq!(tr.final_eval(), Some((30, 0.7)));
    }

    #[test]
    fn summary_known_values() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.mean, 2.5);
        assert_eq!(s.median, 2.5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        let s = Summary::of(&[5.0]);
        assert_eq!(s.median, 5.0);
        assert_eq!(s.std, 0.0);
    }

    #[test]
    fn jsonl_sink_appends() {
        let dir = std::env::temp_dir().join(format!("stepnm_test_{}", std::process::id()));
        let path = dir.join("rows.jsonl");
        let sink = JsonlSink::create(&path).unwrap();
        let mut row = JsonObj::new();
        row.insert("a", Json::Num(1.0));
        sink.append(&row).unwrap();
        sink.append(&row).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn csv_writer_format() {
        let dir = std::env::temp_dir().join(format!("stepnm_csv_{}", std::process::id()));
        let path = dir.join("t.csv");
        write_csv(&path, &["step", "loss"], &[vec![1.0, 0.5], vec![2.0, 0.25]]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("step,loss\n"));
        assert_eq!(text.lines().count(), 3);
        std::fs::remove_dir_all(&dir).ok();
    }
}
