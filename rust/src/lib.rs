//! # step-nm — STEP: Learning N:M Structured Sparsity Masks from Scratch with Precondition
//!
//! A full reproduction of the ICML 2023 paper (Lu et al.) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 1 (build time)** — Pallas kernels (`python/compile/kernels/`):
//!   N:M mask selection, masked matmul, fused optimizer updates. Verified
//!   against a pure-jnp oracle (`ref.py`) by pytest.
//! * **Layer 2 (build time)** — JAX model zoo + per-recipe train/eval step
//!   functions (`python/compile/`), AOT-lowered to HLO text artifacts in
//!   `artifacts/` with a `manifest.json` describing every input/output.
//! * **Layer 3 (run time, this crate)** — the Rust coordinator. It owns all
//!   training state, loads the HLO artifacts through PJRT (the [`runtime`]
//!   module), and drives the paper's recipes: dense Adam / momentum SGD,
//!   STE, SR-STE, ASP, Decaying Mask, and **STEP** with the **AutoSwitch**
//!   phase detector ([`autoswitch`]). Python never runs on the training path.
//!
//! The crate additionally contains a *pure-Rust* experiment engine
//! ([`model`], [`optim`]) used where thousands of steps across many seeds
//! are needed (e.g. Table 1's switch-point statistics) — it is bit-compared
//! against the HLO path by the integration tests. The model layer is the
//! [`model::SparseModel`] trait: the MLP analogs ([`model::Mlp`]) and a
//! pure-Rust attention encoder ([`model::TokenEncoder`] — fused-QKV
//! attention with exact softmax backprop, the paper's BERT/GPT-2 workload
//! family) run the identical train → STEP switch → pack → packed
//! fine-tune → serve pipeline, with manifest checkpoints resolved by
//! [`model::model_from_info`].
//!
//! Once a mask is learned, the **packed inference engine**
//! ([`sparsity::packed`], [`coordinator::serve`]) exports the weights in
//! compressed N:M form (kept values + per-group index codes) and serves
//! batches through sparse kernels that skip pruned slots — the deployment
//! step the paper's A100-2:4 motivation assumes. The **packed backward
//! pass** ([`coordinator::finetune`]) closes the loop for frozen-mask
//! fine-tuning: compact gradients and `n_values()`-sized optimizer state,
//! bit-identical to the dense masked step on kept coordinates. `cargo
//! bench --bench substrate` records packed-vs-dense throughput to
//! `BENCH_inference.json` and `BENCH_finetune.json`.
//!
//! ## Quick tour
//!
//! ```no_run
//! use step_nm::prelude::*;
//!
//! // Load the artifact registry produced by `make artifacts`.
//! let registry = Registry::load("artifacts").unwrap();
//! let rt = Runtime::new(registry).unwrap();
//!
//! // Train the CIFAR-analog MLP with the full STEP recipe.
//! let cfg = ExperimentConfig::builder("mlp_cf10")
//!     .recipe(RecipeKind::Step)
//!     .sparsity(2, 4)
//!     .steps(2000)
//!     .build();
//! let mut session = Session::new(&rt, &cfg).unwrap();
//! let report = session.run().unwrap();
//! println!("final eval accuracy = {:.4}", report.final_eval.primary);
//! ```
//!
//! See `examples/` for runnable end-to-end drivers and `DESIGN.md` for the
//! experiment ↔ module map.

pub mod analysis;
pub mod autoswitch;
pub mod bench;
pub mod checkpoint;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod metrics;
pub mod model;
pub mod optim;
pub mod rng;
pub mod runtime;
pub mod sparsity;
pub mod telemetry;
pub mod tensor;
pub mod testutil;
pub mod util;

/// Convenience re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::autoswitch::{AutoSwitch, SwitchPolicy, SwitchStat};
    pub use crate::config::{ExperimentConfig, RecipeKind};
    pub use crate::coordinator::{
        BatchGenerator, BatchServer, DriverConfig, FinetuneSession, FrontendConfig,
        GenerateConfig, Report, ServeFrontend, Session, Sweep, TrainDriver,
    };
    pub use crate::data::{Dataset, MiniBatchStream, NextTokenTask};
    pub use crate::model::{model_from_info, AnyModel, Mlp, SparseModel, TokenDecoder, TokenEncoder};
    pub use crate::optim::OptimizerKind;
    pub use crate::rng::Pcg64;
    pub use crate::runtime::{Registry, Runtime};
    pub use crate::sparsity::{nm_mask, NmRatio, PackedNmTensor, PackedParam};
    pub use crate::tensor::Tensor;
}
