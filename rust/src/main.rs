//! `step-nm` — the experiment launcher.
//!
//! ```text
//! step-nm train --config configs/e2e_lm.toml      # one training run
//! step-nm train --model mlp_cf10 --recipe step --sparsity 1:4 --steps 800
//! step-nm bench <fig1|fig2|...|table4|perf|all> [--quick|--full]
//! step-nm list                                    # artifacts + models
//! step-nm info                                    # runtime/platform info
//! ```
//!
//! (Hand-rolled argument parsing; the offline image has no clap.)

use step_nm::config::{ExperimentConfig, RecipeKind, TomlDoc};
use step_nm::coordinator::Session;
use step_nm::runtime::{Registry, Runtime};

mod experiments;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &[String]) -> anyhow::Result<()> {
    match args.first().map(String::as_str) {
        Some("train") => cmd_train(&args[1..]),
        Some("bench") => experiments::cmd_bench(&args[1..]),
        Some("list") => cmd_list(),
        Some("info") => cmd_info(),
        Some("help") | None => {
            print_help();
            Ok(())
        }
        Some(other) => {
            print_help();
            anyhow::bail!("unknown subcommand {other:?}")
        }
    }
}

fn print_help() {
    println!(
        "step-nm — STEP: Learning N:M Structured Sparsity Masks from Scratch \
         with Precondition (ICML 2023)\n\n\
         USAGE:\n  step-nm train [--config FILE] [--model KEY] [--recipe R] \
         [--sparsity N:M]\n                [--steps N] [--batch N] [--lr F] [--lam F] \
         [--seed N]\n                [--fixed-switch N] [--eval-every N] [--artifacts DIR]\n  \
         step-nm bench <fig1|fig2|fig3|fig4|fig5|fig6|fig7|fig8|table1|table2|table3|table4|perf|all>\n  \
         \x20             [--quick|--full] [--seeds N] [--artifacts DIR] [--out DIR]\n  \
         step-nm list\n  step-nm info\n\n\
         RECIPES: dense dense_sgdm ste srste srste_sgdm asp step step_v_updated decaying_mask"
    );
}

/// Parse `--key value` pairs into a lookup.
pub fn parse_flags(args: &[String]) -> anyhow::Result<Flags> {
    let mut flags = Flags::default();
    let mut i = 0;
    while i < args.len() {
        let key = &args[i];
        if let Some(name) = key.strip_prefix("--") {
            // boolean flags
            if matches!(name, "quick" | "full" | "verbose") {
                flags.bools.push(name.to_string());
                i += 1;
                continue;
            }
            let val = args
                .get(i + 1)
                .ok_or_else(|| anyhow::anyhow!("flag --{name} needs a value"))?;
            flags.kv.push((name.to_string(), val.clone()));
            i += 2;
        } else {
            flags.positional.push(key.clone());
            i += 1;
        }
    }
    Ok(flags)
}

/// Parsed CLI flags.
#[derive(Debug, Default)]
pub struct Flags {
    pub kv: Vec<(String, String)>,
    pub bools: Vec<String>,
    pub positional: Vec<String>,
}

impl Flags {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.kv
            .iter()
            .rev()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }

    pub fn has(&self, name: &str) -> bool {
        self.bools.iter().any(|b| b == name)
    }

    pub fn get_parse<T: std::str::FromStr>(&self, name: &str) -> anyhow::Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow::anyhow!("--{name} {s:?}: {e}")),
        }
    }
}

fn artifacts_dir(flags: &Flags) -> String {
    flags.get("artifacts").unwrap_or("artifacts").to_string()
}

fn cmd_train(args: &[String]) -> anyhow::Result<()> {
    let flags = parse_flags(args)?;
    let mut cfg = match flags.get("config") {
        Some(path) => ExperimentConfig::from_toml(&TomlDoc::load(path)?)?,
        None => {
            let model = flags
                .get("model")
                .ok_or_else(|| anyhow::anyhow!("need --config or --model"))?;
            ExperimentConfig::builder(model).build()
        }
    };
    // CLI overrides
    if let Some(r) = flags.get("recipe") {
        cfg.recipe = RecipeKind::parse(r)?;
    }
    if let Some(s) = flags.get("sparsity") {
        cfg.ratio = s.parse()?;
    }
    if let Some(v) = flags.get_parse::<usize>("steps")? {
        cfg.steps = v;
    }
    if let Some(v) = flags.get_parse::<usize>("batch")? {
        cfg.batch = v;
    }
    if let Some(v) = flags.get_parse::<f32>("lr")? {
        cfg.lr = v;
    }
    if let Some(v) = flags.get_parse::<f32>("lam")? {
        cfg.lam = v;
    }
    if let Some(v) = flags.get_parse::<u64>("seed")? {
        cfg.seed = v;
    }
    if let Some(v) = flags.get_parse::<usize>("eval-every")? {
        cfg.eval_every = v;
    }
    if let Some(v) = flags.get_parse::<usize>("fixed-switch")? {
        cfg.autoswitch.fixed_step = Some(v);
    }
    cfg.validate()?;

    let rt = Runtime::from_dir(artifacts_dir(&flags))?;
    println!(
        "[train] {} recipe={} sparsity={} steps={} (platform: {})",
        cfg.model,
        cfg.recipe.name(),
        cfg.ratio,
        cfg.steps,
        rt.platform()
    );
    let mut session = Session::new(&rt, &cfg)?;
    let t0 = std::time::Instant::now();
    let report = session.run()?;
    println!(
        "[train] done in {:.1}s: final {}={:.4} (best {:.4}), tail loss {:.4}, switch@{}",
        t0.elapsed().as_secs_f64(),
        report.final_eval.metric_name,
        report.final_eval.primary,
        report.best_eval,
        report.tail_loss,
        report.switch_step
    );
    let st = rt.stats();
    println!(
        "[train] runtime: {} executions, {:.2}s execute, {:.2}s convert, {:.2}s compile",
        st.executions, st.execute_secs, st.convert_secs, st.compile_secs
    );
    Ok(())
}

fn cmd_list() -> anyhow::Result<()> {
    let reg = Registry::load("artifacts")?;
    println!("models:");
    for (key, m) in &reg.manifest.models {
        println!(
            "  {key:<12} kind={:<9} params={:<3} sparse={:<2} dim={} batch={} seq={:?}",
            m.kind,
            m.n_params(),
            m.n_sparse(),
            m.dim,
            m.batch,
            m.seq
        );
    }
    println!("\nartifacts ({}):", reg.manifest.artifacts.len());
    for (name, a) in &reg.manifest.artifacts {
        println!(
            "  {name:<44} recipe={:<18} in={:<3} out={}",
            a.recipe,
            a.inputs.len(),
            a.outputs.len()
        );
    }
    Ok(())
}

fn cmd_info() -> anyhow::Result<()> {
    let rt = Runtime::from_dir("artifacts")?;
    println!("platform      : {}", rt.platform());
    println!("artifacts     : {}", rt.registry().manifest.artifacts.len());
    println!("models        : {}", rt.registry().manifest.models.len());
    Ok(())
}
