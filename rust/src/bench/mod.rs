//! Timing harness (the offline image has no criterion): warmup, fixed-count
//! or fixed-duration iteration, and robust summary stats (mean / p50 / p95 /
//! min), plus a tiny table printer shared by the `benches/` targets and the
//! `step-nm bench` subcommands.

use std::time::{Duration, Instant};

/// Result of one timed benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    /// Per-iteration wall times, seconds.
    pub samples: Vec<f64>,
}

impl BenchResult {
    pub fn mean(&self) -> f64 {
        self.samples.iter().sum::<f64>() / self.samples.len().max(1) as f64
    }

    pub fn min(&self) -> f64 {
        // nm-lint: allow(float-determinism): min is exact and order-independent — no rounding to reassociate
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn percentile(&self, p: f64) -> f64 {
        assert!((0.0..=100.0).contains(&p));
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
        s[idx]
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p95(&self) -> f64 {
        self.percentile(95.0)
    }

    /// Ops (or items) per second at the mean time, given `items` per iter.
    pub fn throughput(&self, items: f64) -> f64 {
        items / self.mean()
    }

    /// One formatted row: `name  mean  p50  p95  min  iters`.
    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>12} {:>12} {:>12} {:>12} {:>8}",
            self.name,
            fmt_time(self.mean()),
            fmt_time(self.p50()),
            fmt_time(self.p95()),
            fmt_time(self.min()),
            self.iters
        )
    }
}

/// Human-friendly seconds formatting (ns → s).
pub fn fmt_time(secs: f64) -> String {
    if !secs.is_finite() {
        "n/a".into()
    } else if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.3}ms", secs * 1e3)
    } else {
        format!("{:.3}s", secs)
    }
}

/// The harness: `warmup` untimed iterations, then time until both `min_iters`
/// and `min_time` are satisfied (capped at `max_iters`).
#[derive(Debug, Clone, Copy)]
pub struct Harness {
    pub warmup: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub min_time: Duration,
}

impl Default for Harness {
    fn default() -> Self {
        Self {
            warmup: 3,
            min_iters: 10,
            max_iters: 1000,
            min_time: Duration::from_millis(300),
        }
    }
}

impl Harness {
    /// Quick profile for slow end-to-end benches.
    pub fn quick() -> Self {
        Self {
            warmup: 1,
            min_iters: 3,
            max_iters: 50,
            min_time: Duration::from_millis(100),
        }
    }

    /// Run `f` repeatedly; the closure's return value is black-boxed so the
    /// optimizer cannot delete the work.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchResult {
        for _ in 0..self.warmup {
            black_box(f());
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        while samples.len() < self.min_iters
            || (start.elapsed() < self.min_time && samples.len() < self.max_iters)
        {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        BenchResult { name: name.to_string(), iters: samples.len(), samples }
    }
}

/// Prevent the optimizer from eliding a value (stable-Rust black box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// One baseline-vs-fused timing pair of the step-throughput suite.
#[derive(Debug, Clone)]
pub struct Comparison {
    pub name: String,
    /// Mean seconds/iter of the unfused reference path.
    pub baseline_mean: f64,
    /// Mean seconds/iter of the fused path.
    pub fused_mean: f64,
}

impl Comparison {
    pub fn speedup(&self) -> f64 {
        self.baseline_mean / self.fused_mean
    }
}

/// Write a before/after comparison suite as a JSON document (e.g.
/// `BENCH_recipes.json`), so future changes can diff throughput trajectories
/// across commits.
///
/// `outputs_bit_equal` records whether the suite asserted bit-identical
/// outputs between the two paths before timing — the CI smoke job checks
/// the flag is present and true in every `BENCH_*.json`, so a comparison
/// can never silently measure two different computations.
pub fn write_comparison_json(
    path: impl AsRef<std::path::Path>,
    suite: &str,
    rows: &[Comparison],
    outputs_bit_equal: bool,
) -> anyhow::Result<()> {
    write_comparison_json_with(path, suite, rows, outputs_bit_equal, &crate::util::json::JsonObj::new())
}

/// [`write_comparison_json`] plus suite-specific top-level fields merged
/// from `extras` (after the standard keys, in `extras`' insertion order) —
/// the serving suite uses this to record latency percentiles and
/// throughput next to the standard comparison rows.
pub fn write_comparison_json_with(
    path: impl AsRef<std::path::Path>,
    suite: &str,
    rows: &[Comparison],
    outputs_bit_equal: bool,
    extras: &crate::util::json::JsonObj,
) -> anyhow::Result<()> {
    use crate::util::json::{Json, JsonObj};
    let mut doc = JsonObj::new();
    doc.insert("suite", Json::Str(suite.to_string()));
    doc.insert("outputs_bit_equal", Json::Bool(outputs_bit_equal));
    let mut arr = Vec::with_capacity(rows.len());
    for r in rows {
        let mut o = JsonObj::new();
        o.insert("name", Json::Str(r.name.clone()));
        o.insert("baseline_mean_s", Json::Num(r.baseline_mean));
        o.insert("fused_mean_s", Json::Num(r.fused_mean));
        o.insert("speedup", Json::Num(r.speedup()));
        arr.push(Json::Obj(o));
    }
    doc.insert("rows", Json::Arr(arr));
    let mean_speedup = if rows.is_empty() {
        0.0
    } else {
        rows.iter().map(Comparison::speedup).sum::<f64>() / rows.len() as f64
    };
    doc.insert("mean_speedup", Json::Num(mean_speedup));
    // which SIMD tier produced these numbers — perf trajectories are only
    // comparable across commits when the dispatch decision is recorded
    doc.insert(
        "dispatch",
        Json::Str(crate::sparsity::Dispatch::active().name().to_string()),
    );
    for key in extras.keys() {
        if let Some(val) = extras.get(key) {
            doc.insert(key, val.clone());
        }
    }
    let path = path.as_ref();
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            crate::util::ensure_dir(dir)?;
        }
    }
    std::fs::write(path, format!("{}\n", Json::Obj(doc).to_string()))
        .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))?;
    Ok(())
}

/// Print the standard bench table header.
pub fn print_header(title: &str) {
    println!("\n== {title} ==");
    println!(
        "{:<44} {:>12} {:>12} {:>12} {:>12} {:>8}",
        "benchmark", "mean", "p50", "p95", "min", "iters"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn harness_runs_and_reports() {
        let h = Harness { warmup: 1, min_iters: 5, max_iters: 5, min_time: Duration::ZERO };
        let r = h.run("noop", || 42);
        assert_eq!(r.iters, 5);
        assert!(r.mean() >= 0.0);
        assert!(r.p50() <= r.p95());
        assert!(r.min() <= r.mean() * 1.0001);
    }

    #[test]
    fn percentile_bounds() {
        let r = BenchResult {
            name: "x".into(),
            iters: 4,
            samples: vec![4.0, 1.0, 3.0, 2.0],
        };
        assert_eq!(r.percentile(0.0), 1.0);
        assert_eq!(r.percentile(100.0), 4.0);
        assert_eq!(r.p50(), 3.0); // round(0.5*3)=2 -> sorted[2]=3
    }

    #[test]
    fn throughput_math() {
        let r = BenchResult { name: "x".into(), iters: 2, samples: vec![0.5, 0.5] };
        assert!((r.throughput(100.0) - 200.0).abs() < 1e-9);
    }

    #[test]
    fn comparison_json_roundtrips() {
        let dir = std::env::temp_dir().join(format!("stepnm_bench_{}", std::process::id()));
        let path = dir.join("BENCH_test.json");
        let rows = vec![
            Comparison { name: "a".into(), baseline_mean: 0.4, fused_mean: 0.1 },
            Comparison { name: "b".into(), baseline_mean: 0.2, fused_mean: 0.1 },
        ];
        write_comparison_json(&path, "unit", &rows, true).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = crate::util::json::Json::parse(text.trim()).unwrap();
        assert_eq!(doc.get("suite").as_str(), Some("unit"));
        assert_eq!(doc.get("outputs_bit_equal").as_bool(), Some(true));
        assert_eq!(doc.get("rows").as_arr().unwrap().len(), 2);
        let mean = doc.get("mean_speedup").as_f64().unwrap();
        assert!((mean - 3.0).abs() < 1e-9, "mean speedup {mean}");
        let tier = doc.get("dispatch").as_str().unwrap();
        assert!(["scalar", "sse2", "avx2", "neon"].contains(&tier), "tier {tier}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn comparison_json_with_extras_merges_fields() {
        use crate::util::json::{Json, JsonObj};
        let dir = std::env::temp_dir().join(format!("stepnm_benchx_{}", std::process::id()));
        let path = dir.join("BENCH_extras.json");
        let rows =
            vec![Comparison { name: "a".into(), baseline_mean: 0.4, fused_mean: 0.2 }];
        let mut extras = JsonObj::new();
        extras.insert("p50_latency_ns", Json::Num(1234.0));
        extras.insert("requests_per_sec", Json::Num(10.0));
        write_comparison_json_with(&path, "serving", &rows, true, &extras).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = crate::util::json::Json::parse(text.trim()).unwrap();
        assert_eq!(doc.get("suite").as_str(), Some("serving"));
        assert_eq!(doc.get("outputs_bit_equal").as_bool(), Some(true));
        // extras land as top-level fields, after the standard keys
        assert_eq!(doc.get("p50_latency_ns").as_f64(), Some(1234.0));
        assert_eq!(doc.get("requests_per_sec").as_f64(), Some(10.0));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn time_formatting() {
        assert!(fmt_time(2.5e-9).ends_with("ns"));
        assert!(fmt_time(2.5e-6).ends_with("µs"));
        assert!(fmt_time(2.5e-3).ends_with("ms"));
        assert!(fmt_time(2.5).ends_with('s'));
    }
}
