//! A pure-Rust causal pre-norm transformer decoder with **exact** backprop —
//! the separate-QKV + LayerNorm model family the legacy manifests describe
//! (GPT-2 fine-tuning under Adam, the paper's §5.3 workload), plus the
//! production half: **incremental decoding** over a per-sequence KV cache
//! so packed weights serve token-by-token batched generation.
//!
//! Architecture per block (pre-norm, residual stream `h`):
//!
//! ```text
//!   a      = LN₁(h)                                  (exact backward, model::norm)
//!   q,k,v  = a @ W_q, a @ W_k, a @ W_v               (separate QKV, sparse-eligible, bias-free)
//!   ctx    = causal_softmax(Q Kᵀ / √d_h) V  per head (j ≤ i only)
//!   h      = h + ctx @ W_o                           (sparse-eligible, bias-free)
//!   b      = LN₂(h)
//!   h      = h + relu(b @ W_fc1 + b_fc1) @ W_fc2 + b_fc2   (sparse-eligible × 2)
//! ```
//!
//! Head: the **last** position's hidden state through a final LayerNorm and
//! a dense vocabulary projection (next-token prediction — the decoder has no
//! pooling choice; it is `Pool::Last` by definition).
//!
//! **One core, three entry forms.** Training and one-shot inference run the
//! shared `WeightsView` core exactly like [`super::TokenEncoder`]; the third
//! form is [`decode_step`](TokenDecoder::decode_step) /
//! [`decode_step_packed`](TokenDecoder::decode_step_packed): advance every
//! sequence in a batch by ONE token against a [`DecoderKvCache`]. Because
//! LayerNorm is per-row, every matmul kernel computes output rows
//! independently in a pinned ascending-k order, and the causal attention for
//! row `t` reads keys/values `0..=t` in ascending `j` with the identical
//! loop structure as the full forward, the decode step reproduces the full
//! dense masked forward **bit-for-bit** at every position — the generation
//! analog of the repo's packed-vs-dense contract, gated in
//! `rust/tests/decoder_generation.rs` and `BENCH_generation.json`.

use super::norm::{layer_norm, layer_norm_backward, LnCache};
use super::weights::{colsum, WeightsView};
use crate::rng::Pcg64;
use crate::runtime::ModelInfo;
use crate::sparsity::dispatch::{self, Dispatch};
use crate::sparsity::{PackedGrad, PackedParam};
use crate::tensor::{add_bias, axpy, cross_entropy_with_grad, Tensor};

/// Parameter tensors per decoder block: `[ln1_g, ln1_b, wq, wk, wv, wo,
/// ln2_g, ln2_b, fc1_w, fc1_b, fc2_w, fc2_b]`.
pub const DEC_BLOCK_PARAMS: usize = 12;

/// Parameter tensors outside the blocks: `tok_emb`, `pos_emb` up front;
/// `lnf_g`, `lnf_b`, `head_w`, `head_b` at the tail.
pub const DEC_EXTRA_PARAMS: usize = 6;

/// A pure-Rust causal decoder implementing [`super::SparseModel`] — the
/// next-token LM counterpart of [`super::TokenEncoder`], with LayerNorm and
/// separate QKV projections (the legacy manifest layout).
#[derive(Debug, Clone)]
pub struct TokenDecoder {
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub n_blocks: usize,
    pub max_seq: usize,
}

/// Per-block forward caches the backward pass replays.
struct DecBlockCache {
    /// LN₁ byproducts (normalized input + inverse std).
    ln1: LnCache,
    /// Post-LN₁ activations `[B·S, d]` (the QKV matmul input).
    a: Tensor,
    q: Tensor,
    k: Tensor,
    v: Tensor,
    /// Causal attention probabilities, `[B, H, S, S]` row-major; entries
    /// above the diagonal are never written and never read.
    probs: Vec<f32>,
    /// Per-head context `[B·S, d]`.
    ctx: Tensor,
    /// LN₂ byproducts.
    ln2: LnCache,
    /// Post-LN₂ activations `[B·S, d]` (the FFN input).
    bv: Tensor,
    /// Post-ReLU FFN hidden `[B·S, d_ff]`.
    ff_r: Tensor,
}

/// The whole forward pass: caches + head intermediates + logits.
struct DecForwardPass {
    blocks: Vec<DecBlockCache>,
    /// Final-LN byproducts over the pooled rows.
    lnf: LnCache,
    /// Post-final-LN pooled rows `[B, d]` (the head matmul input).
    pn: Tensor,
    logits: Tensor,
    /// Validated token ids (reused by the embedding backward).
    ids: Vec<usize>,
    bsz: usize,
    seq: usize,
}

/// Per-sequence key/value cache for incremental decoding: one `[bsz,
/// max_seq, d]` buffer pair per block, filled left to right as
/// [`TokenDecoder::decode_step`] advances. Rows are appended at the step
/// index, so cached keys/values carry the exact bits the full forward
/// would compute for the same prefix.
pub struct DecoderKvCache {
    bsz: usize,
    max_seq: usize,
    d: usize,
    len: usize,
    /// Per block: keys, `[bsz * max_seq * d]` row-major.
    k: Vec<Vec<f32>>,
    /// Per block: values, same layout.
    v: Vec<Vec<f32>>,
}

impl DecoderKvCache {
    /// Number of sequences currently tracked.
    pub fn bsz(&self) -> usize {
        self.bsz
    }

    /// Number of positions already decoded (the next step writes here).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Drop finished sequences: `keep[r]` says whether sequence `r`
    /// survives. Kept sequences are compacted in order with plain row-chunk
    /// copies (`copy_within`), so surviving cache entries keep their exact
    /// bits and their position indexing — eviction can never perturb the
    /// bit-identity contract.
    pub fn evict(&mut self, keep: &[bool]) -> anyhow::Result<()> {
        anyhow::ensure!(
            keep.len() == self.bsz,
            "evict mask covers {} sequences, cache holds {}",
            keep.len(),
            self.bsz
        );
        let stride = self.max_seq * self.d;
        let kept = keep.iter().filter(|&&f| f).count();
        for buf in self.k.iter_mut().chain(self.v.iter_mut()) {
            let mut w = 0usize;
            for (r, &f) in keep.iter().enumerate() {
                if f {
                    if w != r {
                        buf.copy_within(r * stride..(r + 1) * stride, w * stride);
                    }
                    w += 1;
                }
            }
            buf.truncate(kept * stride);
        }
        self.bsz = kept;
        Ok(())
    }
}

impl TokenDecoder {
    /// A causal next-token decoder. Head count must divide `d_model`.
    pub fn new(
        vocab: usize,
        d_model: usize,
        n_heads: usize,
        d_ff: usize,
        n_blocks: usize,
        max_seq: usize,
    ) -> Self {
        assert!(vocab >= 1 && d_model >= 1 && d_ff >= 1 && n_blocks >= 1 && max_seq >= 1);
        assert!(
            n_heads >= 1 && d_model % n_heads == 0,
            "d_model {d_model} must divide into {n_heads} heads"
        );
        Self { vocab, d_model, n_heads, d_ff, n_blocks, max_seq }
    }

    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub fn n_params(&self) -> usize {
        DEC_EXTRA_PARAMS + DEC_BLOCK_PARAMS * self.n_blocks
    }

    /// Expected shape of every parameter tensor, in order.
    pub fn param_shapes(&self) -> Vec<Vec<usize>> {
        let d = self.d_model;
        let mut out = Vec::with_capacity(self.n_params());
        out.push(vec![self.vocab, d]);
        out.push(vec![self.max_seq, d]);
        for _ in 0..self.n_blocks {
            out.push(vec![d]); // ln1_g
            out.push(vec![d]); // ln1_b
            out.push(vec![d, d]); // wq
            out.push(vec![d, d]); // wk
            out.push(vec![d, d]); // wv
            out.push(vec![d, d]); // wo
            out.push(vec![d]); // ln2_g
            out.push(vec![d]); // ln2_b
            out.push(vec![d, self.d_ff]); // fc1_w
            out.push(vec![self.d_ff]); // fc1_b
            out.push(vec![self.d_ff, d]); // fc2_w
            out.push(vec![d]); // fc2_b
        }
        out.push(vec![d]); // lnf_g
        out.push(vec![d]); // lnf_b
        out.push(vec![d, self.vocab]); // head_w
        out.push(vec![self.vocab]); // head_b
        out
    }

    /// Parameter names matching [`param_shapes`](Self::param_shapes), in
    /// the legacy manifest convention (`l{b}_wq`, `l{b}_fc1_w`, …). A
    /// single-head decoder writes plain `pos_emb` — exactly the legacy
    /// layout — while multi-head decoders tag the head count as
    /// `pos_emb_h{heads}` so [`from_model_info`](Self::from_model_info)
    /// can round-trip the architecture.
    pub fn param_names(&self) -> Vec<String> {
        let mut out = Vec::with_capacity(self.n_params());
        out.push("tok_emb".to_string());
        if self.n_heads == 1 {
            out.push("pos_emb".to_string());
        } else {
            out.push(format!("pos_emb_h{}", self.n_heads));
        }
        for b in 0..self.n_blocks {
            for suffix in [
                "ln1_g", "ln1_b", "wq", "wk", "wv", "wo", "ln2_g", "ln2_b", "fc1_w", "fc1_b",
                "fc2_w", "fc2_b",
            ] {
                out.push(format!("l{b}_{suffix}"));
            }
        }
        out.push("lnf_g".to_string());
        out.push("lnf_b".to_string());
        out.push("head_w".to_string());
        out.push("head_b".to_string());
        out
    }

    /// Sparse-eligibility per parameter: the six block projections yes,
    /// embeddings / LayerNorm affines / biases / head no.
    pub fn sparse_flags(&self) -> Vec<bool> {
        let mut out = vec![false, false];
        for _ in 0..self.n_blocks {
            out.extend_from_slice(&[
                false, false, // ln1
                true, true, true, true, // wq wk wv wo
                false, false, // ln2
                true, false, // fc1_w fc1_b
                true, false, // fc2_w fc2_b
            ]);
        }
        out.extend_from_slice(&[false, false, false, false]);
        out
    }

    /// Fan-in-scaled init (weights ~ N(0, 1/√fan_in), embeddings ~
    /// N(0, 0.05), LayerNorm gains one, every other 1-D tensor zero), one
    /// sequential draw per tensor in layout order (deterministic in the
    /// rng).
    pub fn init(&self, rng: &mut Pcg64) -> Vec<Tensor> {
        let names = self.param_names();
        self.param_shapes()
            .into_iter()
            .enumerate()
            .map(|(i, shape)| {
                if i < 2 {
                    Tensor::randn(&shape, rng, 0.0, 0.05) // embeddings
                } else if shape.len() == 2 {
                    let scale = 1.0 / (shape[0] as f32).sqrt();
                    Tensor::randn(&shape, rng, 0.0, scale)
                } else if names[i].ends_with("_g") {
                    Tensor::full(&shape, 1.0) // LayerNorm gains
                } else {
                    Tensor::zeros(&shape) // biases + LayerNorm shifts
                }
            })
            .collect()
    }

    // ---- layout indexing ---------------------------------------------------

    /// First parameter index of block `b` (its `ln1_g`).
    fn i_block(&self, b: usize) -> usize {
        2 + DEC_BLOCK_PARAMS * b
    }

    /// First tail index (`lnf_g`).
    fn i_tail(&self) -> usize {
        2 + DEC_BLOCK_PARAMS * self.n_blocks
    }

    // ---- the shared core ---------------------------------------------------

    /// The single validity rule for an f32-carried token id — shared by the
    /// forward's panic gate, the serve-time error gate (`validate_input`)
    /// and the decode step's `ensure!`, so the three can never drift.
    fn is_token_id(&self, v: f32) -> bool {
        v.is_finite() && v >= 0.0 && v.fract() == 0.0 && (v as usize) < self.vocab
    }

    /// Validate and read the token ids out of the f32 input tensor.
    fn token_ids(&self, x: &Tensor) -> (usize, usize, Vec<usize>) {
        let (bsz, seq) = x.as_2d();
        assert!(seq >= 1, "decoder input needs at least one token");
        assert!(
            seq <= self.max_seq,
            "sequence length {seq} exceeds max_seq {}",
            self.max_seq
        );
        let ids: Vec<usize> = x
            .data()
            .iter()
            .map(|&v| {
                assert!(
                    self.is_token_id(v),
                    "token id {v} out of range for vocab {}",
                    self.vocab
                );
                v as usize
            })
            .collect();
        (bsz, seq, ids)
    }

    /// Causal attention forward for one block: probabilities (lower
    /// triangle only) + context. Row `i` attends to `j ∈ 0..=i` in
    /// ascending order — the loop structure the decode step reproduces
    /// exactly, which is the whole bit-identity argument.
    fn causal_attention_forward(
        &self,
        q: &Tensor,
        k: &Tensor,
        v: &Tensor,
        bsz: usize,
        seq: usize,
    ) -> (Vec<f32>, Tensor) {
        let d = self.d_model;
        let heads = self.n_heads;
        let dh = self.head_dim();
        let scale = 1.0 / (dh as f32).sqrt();
        let disp = Dispatch::active();
        let qd = q.data();
        let kd = k.data();
        let vd = v.data();
        let mut probs = vec![0f32; bsz * heads * seq * seq];
        let mut ctx = Tensor::zeros(&[bsz * seq, d]);
        let cd = ctx.data_mut();
        // Transposed key panel for one sequence: kt[c][j] = k_j[c] — pure
        // data movement so the SIMD score columns read contiguous keys.
        let mut kt = vec![0f32; d * seq];
        for b in 0..bsz {
            for j in 0..seq {
                let krow = &kd[(b * seq + j) * d..][..d];
                for (c, &v2) in krow.iter().enumerate() {
                    kt[c * seq + j] = v2;
                }
            }
            for i in 0..seq {
                let qrow = &qd[(b * seq + i) * d..][..d];
                let pbase = ((b * heads) * seq + i) * seq;
                // causal scores for all heads of row i: j ≤ i only
                dispatch::attn_scores_all_heads(
                    disp,
                    qrow,
                    &kt,
                    seq,
                    i + 1,
                    dh,
                    scale,
                    &mut probs[pbase..],
                    seq * seq,
                );
                for h in 0..heads {
                    let prow = &mut probs[pbase + h * seq * seq..][..i + 1];
                    // row max over the visible prefix, ascending j
                    let mut mx = f32::NEG_INFINITY;
                    for &p in prow.iter() {
                        if p > mx {
                            mx = p;
                        }
                    }
                    // exact softmax over the visible prefix
                    let mut denom = 0f64;
                    for p in prow.iter_mut() {
                        let e = ((*p - mx) as f64).exp();
                        *p = e as f32;
                        denom += e;
                    }
                    for p in prow.iter_mut() {
                        *p = ((*p as f64) / denom) as f32;
                    }
                }
                // ctx_i = Σ_{j≤i} p_ij · v_j for every head, ascending j
                let crow = &mut cd[(b * seq + i) * d..][..d];
                dispatch::attn_context_all_heads(
                    disp,
                    &probs[pbase..],
                    seq * seq,
                    i + 1,
                    &vd[(b * seq) * d..],
                    d,
                    dh,
                    crow,
                );
            }
        }
        (probs, ctx)
    }

    /// Exact causal attention backward: `(dq, dk, dv)` from `d_ctx`, the
    /// stored probabilities and the forward activations. The softmax
    /// Jacobian is applied in closed form over the visible prefix only:
    /// `ds = p ⊙ (dp − Σ_{j≤i} p_j dp_j)`.
    #[allow(clippy::too_many_arguments)]
    fn causal_attention_backward(
        &self,
        q: &Tensor,
        k: &Tensor,
        v: &Tensor,
        probs: &[f32],
        d_ctx: &Tensor,
        bsz: usize,
        seq: usize,
    ) -> (Tensor, Tensor, Tensor) {
        let d = self.d_model;
        let heads = self.n_heads;
        let dh = self.head_dim();
        let scale = 1.0 / (dh as f32).sqrt();
        let qd = q.data();
        let kd = k.data();
        let vd = v.data();
        let dcd = d_ctx.data();
        let mut dq = Tensor::zeros(&[bsz * seq, d]);
        let mut dk = Tensor::zeros(&[bsz * seq, d]);
        let mut dv = Tensor::zeros(&[bsz * seq, d]);
        let dqd = dq.data_mut();
        let dkd = dk.data_mut();
        let dvd = dv.data_mut();
        let mut dp = vec![0f32; seq];
        for b in 0..bsz {
            for h in 0..heads {
                let col = h * dh;
                for i in 0..seq {
                    let prow = &probs[((b * heads + h) * seq + i) * seq..][..i + 1];
                    let dcrow = &dcd[(b * seq + i) * d + col..][..dh];
                    // dV_j += p_ij · dctx_i ; dp_ij = dctx_i · v_j
                    for (j, &p) in prow.iter().enumerate() {
                        let vrow = &vd[(b * seq + j) * d + col..][..dh];
                        let dvrow = &mut dvd[(b * seq + j) * d + col..][..dh];
                        let mut acc = 0f32;
                        for t in 0..dh {
                            acc += dcrow[t] * vrow[t];
                            dvrow[t] += p * dcrow[t];
                        }
                        dp[j] = acc;
                    }
                    // softmax Jacobian row over j ≤ i
                    let mut inner = 0f64;
                    for (&p, &g) in prow.iter().zip(dp.iter()) {
                        inner += (p as f64) * (g as f64);
                    }
                    let inner = inner as f32;
                    let qrow = &qd[(b * seq + i) * d + col..][..dh];
                    for (j, &p) in prow.iter().enumerate() {
                        let ds = p * (dp[j] - inner) * scale;
                        if ds == 0.0 {
                            continue; // zero rows add exact zeros on both paths
                        }
                        let krow = &kd[(b * seq + j) * d + col..][..dh];
                        let dkrow = &mut dkd[(b * seq + j) * d + col..][..dh];
                        for t in 0..dh {
                            dkrow[t] += ds * qrow[t];
                        }
                        let dqrow = &mut dqd[(b * seq + i) * d + col..][..dh];
                        for t in 0..dh {
                            dqrow[t] += ds * krow[t];
                        }
                    }
                }
            }
        }
        (dq, dk, dv)
    }

    /// The full forward pass with caches (shared by inference and training;
    /// the storage form only changes which matmul kernels run).
    fn run_forward(&self, w: &WeightsView, x: &Tensor) -> DecForwardPass {
        let (bsz, seq, ids) = self.token_ids(x);
        let d = self.d_model;
        // embed: tok[id] + pos[s]
        let tok = w.tensor(0);
        let pos = w.tensor(1);
        let mut h = Tensor::zeros(&[bsz * seq, d]);
        {
            let td = tok.data();
            let pd = pos.data();
            let hd = h.data_mut();
            for r in 0..bsz {
                for s in 0..seq {
                    let id = ids[r * seq + s];
                    let row = &mut hd[(r * seq + s) * d..][..d];
                    let trow = &td[id * d..][..d];
                    let prow = &pd[s * d..][..d];
                    for j in 0..d {
                        row[j] = trow[j] + prow[j];
                    }
                }
            }
        }
        let mut blocks = Vec::with_capacity(self.n_blocks);
        for blk in 0..self.n_blocks {
            let ib = self.i_block(blk);
            let (a, ln1) = layer_norm(&h, w.tensor(ib), w.tensor(ib + 1));
            let q = w.matmul(&a, ib + 2);
            let k = w.matmul(&a, ib + 3);
            let v = w.matmul(&a, ib + 4);
            let (probs, ctx) = self.causal_attention_forward(&q, &k, &v, bsz, seq);
            let attn_out = w.matmul(&ctx, ib + 5);
            let mut h_mid = h;
            axpy(&mut h_mid, 1.0, &attn_out);
            let (bv, ln2) = layer_norm(&h_mid, w.tensor(ib + 6), w.tensor(ib + 7));
            let mut ff = w.matmul(&bv, ib + 8);
            add_bias(&mut ff, w.tensor(ib + 9));
            let ff_r = crate::tensor::relu(&ff);
            let mut ff_out = w.matmul(&ff_r, ib + 10);
            add_bias(&mut ff_out, w.tensor(ib + 11));
            let mut h_out = h_mid;
            axpy(&mut h_out, 1.0, &ff_out);
            blocks.push(DecBlockCache { ln1, a, q, k, v, probs, ctx, ln2, bv, ff_r });
            h = h_out;
        }
        // pool the last position per sequence, final LN, dense head
        let mut pooled = Tensor::zeros(&[bsz, d]);
        {
            let hd = h.data();
            let pd = pooled.data_mut();
            for r in 0..bsz {
                pd[r * d..(r + 1) * d].copy_from_slice(&hd[(r * seq + seq - 1) * d..][..d]);
            }
        }
        let it = self.i_tail();
        let (pn, lnf) = layer_norm(&pooled, w.tensor(it), w.tensor(it + 1));
        let mut logits = w.matmul(&pn, it + 2);
        add_bias(&mut logits, w.tensor(it + 3));
        DecForwardPass { blocks, lnf, pn, logits, ids, bsz, seq }
    }

    /// Loss + gradients through the shared core; the grad of parameter `i`
    /// is compact exactly when `w` stores it packed.
    fn core_loss_and_grad(
        &self,
        w: &WeightsView,
        x: &Tensor,
        labels: &[usize],
    ) -> (f64, Vec<PackedGrad>) {
        let fwd = self.run_forward(w, x);
        let (bsz, seq) = (fwd.bsz, fwd.seq);
        let d = self.d_model;
        let (loss, dlogits) = cross_entropy_with_grad(&fwd.logits, labels);

        let mut grads: Vec<PackedGrad> = (0..self.n_params())
            .map(|_| PackedGrad::Dense(Tensor::zeros(&[0])))
            .collect();

        // head + final LayerNorm
        let it = self.i_tail();
        grads[it + 2] = w.grad_w(&fwd.pn, &dlogits, it + 2);
        grads[it + 3] = PackedGrad::Dense(colsum(&dlogits));
        let dpn = w.matmul_bt(&dlogits, it + 2);
        let (dpooled, dgf, dbf) = layer_norm_backward(&dpn, w.tensor(it), &fwd.lnf);
        grads[it] = PackedGrad::Dense(dgf);
        grads[it + 1] = PackedGrad::Dense(dbf);

        // scatter the pooled gradient back into the last position
        let mut dh = Tensor::zeros(&[bsz * seq, d]);
        {
            let dpd = dpooled.data();
            let dhd = dh.data_mut();
            for r in 0..bsz {
                dhd[(r * seq + seq - 1) * d..][..d].copy_from_slice(&dpd[r * d..(r + 1) * d]);
            }
        }

        for blk in (0..self.n_blocks).rev() {
            let c = &fwd.blocks[blk];
            let ib = self.i_block(blk);
            // ---- FFN backward (residual: h_out = h_mid + ffn(LN₂(h_mid))) ----
            grads[ib + 10] = w.grad_w(&c.ff_r, &dh, ib + 10);
            grads[ib + 11] = PackedGrad::Dense(colsum(&dh));
            let mut dr = w.matmul_bt(&dh, ib + 10);
            for (g, &r) in dr.data_mut().iter_mut().zip(c.ff_r.data()) {
                if r <= 0.0 {
                    *g = 0.0; // ReLU gate, same convention as the MLP
                }
            }
            grads[ib + 8] = w.grad_w(&c.bv, &dr, ib + 8);
            grads[ib + 9] = PackedGrad::Dense(colsum(&dr));
            let dbv = w.matmul_bt(&dr, ib + 8);
            let (dh_mid_ln, dg2, db2) = layer_norm_backward(&dbv, w.tensor(ib + 6), &c.ln2);
            grads[ib + 6] = PackedGrad::Dense(dg2);
            grads[ib + 7] = PackedGrad::Dense(db2);
            let mut dh_mid = dh; // the residual passes dh through unchanged
            axpy(&mut dh_mid, 1.0, &dh_mid_ln);

            // ---- attention backward (residual: h_mid = h_in + ctx @ W_o) ----
            grads[ib + 5] = w.grad_w(&c.ctx, &dh_mid, ib + 5);
            let dctx = w.matmul_bt(&dh_mid, ib + 5);
            let (dq, dk, dv) =
                self.causal_attention_backward(&c.q, &c.k, &c.v, &c.probs, &dctx, bsz, seq);
            grads[ib + 2] = w.grad_w(&c.a, &dq, ib + 2);
            grads[ib + 3] = w.grad_w(&c.a, &dk, ib + 3);
            grads[ib + 4] = w.grad_w(&c.a, &dv, ib + 4);
            let mut da = w.matmul_bt(&dq, ib + 2);
            axpy(&mut da, 1.0, &w.matmul_bt(&dk, ib + 3));
            axpy(&mut da, 1.0, &w.matmul_bt(&dv, ib + 4));
            let (dh_ln1, dg1, db1) = layer_norm_backward(&da, w.tensor(ib), &c.ln1);
            grads[ib] = PackedGrad::Dense(dg1);
            grads[ib + 1] = PackedGrad::Dense(db1);
            let mut dh_in = dh_mid;
            axpy(&mut dh_in, 1.0, &dh_ln1);
            dh = dh_in;
        }

        // embeddings: scatter-add per token id / position (ids validated
        // once by the forward pass)
        let ids = &fwd.ids;
        let mut dtok = Tensor::zeros(&[self.vocab, d]);
        let mut dpos = Tensor::zeros(&[self.max_seq, d]);
        {
            let dhd = dh.data();
            let dtd = dtok.data_mut();
            let dpd = dpos.data_mut();
            for r in 0..bsz {
                for s in 0..seq {
                    let row = &dhd[(r * seq + s) * d..][..d];
                    let id = ids[r * seq + s];
                    let trow = &mut dtd[id * d..][..d];
                    for j in 0..d {
                        trow[j] += row[j];
                    }
                    let prow = &mut dpd[s * d..][..d];
                    for j in 0..d {
                        prow[j] += row[j];
                    }
                }
            }
        }
        grads[0] = PackedGrad::Dense(dtok);
        grads[1] = PackedGrad::Dense(dpos);
        (loss, grads)
    }

    // ---- incremental decoding ---------------------------------------------

    /// An empty KV cache for `bsz` sequences advancing in lock step.
    pub fn new_cache(&self, bsz: usize) -> DecoderKvCache {
        let stride = self.max_seq * self.d_model;
        DecoderKvCache {
            bsz,
            max_seq: self.max_seq,
            d: self.d_model,
            len: 0,
            k: (0..self.n_blocks).map(|_| vec![0f32; bsz * stride]).collect(),
            v: (0..self.n_blocks).map(|_| vec![0f32; bsz * stride]).collect(),
        }
    }

    /// Advance every sequence by one token over dense weights: `ids[r]` is
    /// the token at position `cache.len()` of sequence `r`; returns the
    /// next-token logits `[bsz, vocab]`. Bit-identical, per sequence and
    /// step, to [`forward`](Self::forward) over the full prefix.
    pub fn decode_step(
        &self,
        params: &[Tensor],
        cache: &mut DecoderKvCache,
        ids: &[usize],
    ) -> anyhow::Result<Tensor> {
        anyhow::ensure!(
            params.len() == self.n_params(),
            "decoder param arity: {} vs {}",
            params.len(),
            self.n_params()
        );
        self.decode_core(&WeightsView::Dense(params), cache, ids)
    }

    /// [`decode_step`](Self::decode_step) over packed N:M weights —
    /// bit-identical to the dense masked decode by the shared-core
    /// construction plus the packed kernel equalities.
    pub fn decode_step_packed(
        &self,
        params: &[PackedParam],
        cache: &mut DecoderKvCache,
        ids: &[usize],
    ) -> anyhow::Result<Tensor> {
        anyhow::ensure!(
            params.len() == self.n_params(),
            "decoder packed param arity: {} vs {}",
            params.len(),
            self.n_params()
        );
        let cols: Vec<Option<Vec<u32>>> = vec![None; params.len()];
        self.decode_core(&WeightsView::Packed { params, cols: &cols }, cache, ids)
    }

    /// The single-token forward: one embedding row per sequence, per-block
    /// LN → QKV → causal attention against the cache → FFN, appending this
    /// step's keys/values at position `cache.len()`. Every loop mirrors the
    /// full forward's loop for row `t` exactly (same kernels, same
    /// ascending-j accumulation), which is what makes the step bit-exact.
    fn decode_core(
        &self,
        w: &WeightsView,
        cache: &mut DecoderKvCache,
        ids: &[usize],
    ) -> anyhow::Result<Tensor> {
        let d = self.d_model;
        anyhow::ensure!(
            cache.d == d && cache.max_seq == self.max_seq && cache.k.len() == self.n_blocks,
            "KV cache was built for a different decoder (d {} seq {} blocks {})",
            cache.d,
            cache.max_seq,
            cache.k.len()
        );
        let bsz = cache.bsz;
        anyhow::ensure!(bsz >= 1, "KV cache tracks no sequences");
        anyhow::ensure!(
            ids.len() == bsz,
            "decode step got {} ids for {} cached sequences",
            ids.len(),
            bsz
        );
        let t = cache.len;
        anyhow::ensure!(
            t < self.max_seq,
            "KV cache is full: position {t} at max_seq {}",
            self.max_seq
        );
        for (r, &id) in ids.iter().enumerate() {
            anyhow::ensure!(
                id < self.vocab,
                "sequence {r}: token id {id} out of range for vocab {}",
                self.vocab
            );
        }
        let heads = self.n_heads;
        let dh = self.head_dim();
        let scale = 1.0 / (dh as f32).sqrt();
        let stride = self.max_seq * d;
        // embed this position: tok[id] + pos[t]
        let mut h = Tensor::zeros(&[bsz, d]);
        {
            let td = w.tensor(0).data();
            let pd = w.tensor(1).data();
            let hd = h.data_mut();
            for (r, &id) in ids.iter().enumerate() {
                let row = &mut hd[r * d..(r + 1) * d];
                let trow = &td[id * d..][..d];
                let prow = &pd[t * d..][..d];
                for j in 0..d {
                    row[j] = trow[j] + prow[j];
                }
            }
        }
        // One score row per head: head hh's scores live at
        // prow[hh * (t + 1)..][..t + 1] — a single kernel call covers all
        // heads of a sequence.
        let mut prow = vec![0f32; heads * (t + 1)];
        for blk in 0..self.n_blocks {
            let ib = self.i_block(blk);
            let (a, _ln1) = layer_norm(&h, w.tensor(ib), w.tensor(ib + 1));
            let q = w.matmul(&a, ib + 2);
            let k_new = w.matmul(&a, ib + 3);
            let v_new = w.matmul(&a, ib + 4);
            // append this step's keys/values at position t
            {
                let kbuf = &mut cache.k[blk];
                let vbuf = &mut cache.v[blk];
                let knd = k_new.data();
                let vnd = v_new.data();
                for r in 0..bsz {
                    kbuf[(r * self.max_seq + t) * d..][..d]
                        .copy_from_slice(&knd[r * d..(r + 1) * d]);
                    vbuf[(r * self.max_seq + t) * d..][..d]
                        .copy_from_slice(&vnd[r * d..(r + 1) * d]);
                }
            }
            // causal attention for row t against the cached prefix 0..=t —
            // the exact term order of causal_attention_forward at i = t,
            // batched so one kernel call covers every head of a sequence.
            // Keys stay row-major (the cache layout): transposing here
            // would cost as much as the dots themselves at kv = t + 1.
            let mut ctx = Tensor::zeros(&[bsz, d]);
            {
                let qd = q.data();
                let kbuf = &cache.k[blk];
                let vbuf = &cache.v[blk];
                let cd = ctx.data_mut();
                let disp = Dispatch::active();
                for r in 0..bsz {
                    let qrow = &qd[r * d..][..d];
                    dispatch::attn_scores_rows_all_heads(
                        qrow,
                        &kbuf[r * stride..],
                        d,
                        t + 1,
                        dh,
                        scale,
                        &mut prow,
                        t + 1,
                    );
                    for hh in 0..heads {
                        let ph = &mut prow[hh * (t + 1)..][..t + 1];
                        let mut mx = f32::NEG_INFINITY;
                        for &p in ph.iter() {
                            if p > mx {
                                mx = p;
                            }
                        }
                        let mut denom = 0f64;
                        for p in ph.iter_mut() {
                            let e = ((*p - mx) as f64).exp();
                            *p = e as f32;
                            denom += e;
                        }
                        for p in ph.iter_mut() {
                            *p = ((*p as f64) / denom) as f32;
                        }
                    }
                    let crow = &mut cd[r * d..][..d];
                    dispatch::attn_context_all_heads(
                        disp,
                        &prow,
                        t + 1,
                        t + 1,
                        &vbuf[r * stride..],
                        d,
                        dh,
                        crow,
                    );
                }
            }
            let attn_out = w.matmul(&ctx, ib + 5);
            let mut h_mid = h;
            axpy(&mut h_mid, 1.0, &attn_out);
            let (bv, _ln2) = layer_norm(&h_mid, w.tensor(ib + 6), w.tensor(ib + 7));
            let mut ff = w.matmul(&bv, ib + 8);
            add_bias(&mut ff, w.tensor(ib + 9));
            let ff_r = crate::tensor::relu(&ff);
            let mut ff_out = w.matmul(&ff_r, ib + 10);
            add_bias(&mut ff_out, w.tensor(ib + 11));
            let mut h_out = h_mid;
            axpy(&mut h_out, 1.0, &ff_out);
            h = h_out;
        }
        cache.len = t + 1;
        let it = self.i_tail();
        let (pn, _lnf) = layer_norm(&h, w.tensor(it), w.tensor(it + 1));
        let mut logits = w.matmul(&pn, it + 2);
        add_bias(&mut logits, w.tensor(it + 3));
        Ok(logits)
    }

    // ---- inherent conveniences (the trait impl delegates here) -----------

    /// Dense forward: next-token logits `[batch, vocab]` from token ids
    /// `[batch, seq]` (the last position's prediction).
    pub fn forward(&self, params: &[Tensor], x: &Tensor) -> Tensor {
        assert_eq!(params.len(), self.n_params(), "decoder param arity");
        self.run_forward(&WeightsView::Dense(params), x).logits
    }

    /// Packed forward — bit-identical to [`forward`](Self::forward) over
    /// the dense masked weights on finite inputs.
    pub fn forward_packed(&self, params: &[PackedParam], x: &Tensor) -> Tensor {
        assert_eq!(params.len(), self.n_params(), "decoder packed param arity");
        let cols: Vec<Option<Vec<u32>>> = vec![None; params.len()];
        self.run_forward(&WeightsView::Packed { params, cols: &cols }, x)
            .logits
    }

    /// Dense loss + exact gradients.
    pub fn loss_and_grad(
        &self,
        params: &[Tensor],
        x: &Tensor,
        labels: &[usize],
    ) -> (f64, Vec<Tensor>) {
        assert_eq!(params.len(), self.n_params(), "decoder param arity");
        let (loss, grads) = self.core_loss_and_grad(&WeightsView::Dense(params), x, labels);
        let grads = grads
            .into_iter()
            .map(|g| match g {
                PackedGrad::Dense(t) => t,
                // nm-lint: allow(panic-freedom): core_loss_and_grad returns Compact only for packed views; this branch is the Dense view
                PackedGrad::Compact(_) => unreachable!("dense path yields dense grads"),
            })
            .collect();
        (loss, grads)
    }

    /// Describe this decoder as a manifest-style [`ModelInfo`]; the layout
    /// (names + shapes) is sufficient to rebuild the architecture via
    /// [`from_model_info`](Self::from_model_info). Single-head decoders
    /// emit the plain `pos_emb` name — byte-for-byte the legacy manifest
    /// layout.
    pub fn model_info(&self, key: &str, batch: usize) -> ModelInfo {
        let names = self.param_names();
        let shapes = self.param_shapes();
        let flags = self.sparse_flags();
        let params: Vec<(String, Vec<usize>, bool)> = names
            .into_iter()
            .zip(shapes)
            .zip(flags.iter().copied())
            .map(|((n, s), f)| (n, s, f))
            .collect();
        let sparse_indices = flags
            .iter()
            .enumerate()
            .filter_map(|(i, &s)| s.then_some(i))
            .collect();
        let dim = params.iter().map(|(_, s, _)| s.iter().product::<usize>()).sum();
        ModelInfo {
            key: key.to_string(),
            params,
            sparse_indices,
            kind: "lm".to_string(),
            n_classes: self.vocab,
            dim,
            batch,
            seq: Some(self.max_seq),
        }
    }

    /// Rebuild a [`TokenDecoder`] from a manifest layout: `tok_emb`, a
    /// position embedding (plain `pos_emb` reads as one head — the legacy
    /// convention — or `pos_emb_h{heads}`), separate-QKV LayerNorm blocks
    /// of [`DEC_BLOCK_PARAMS`] tensors, and a final-LN vocabulary head.
    /// Only kind `"lm"` dispatches here: the decoder is a next-token model
    /// by construction.
    pub fn from_model_info(info: &ModelInfo) -> anyhow::Result<Self> {
        anyhow::ensure!(
            info.kind == "lm",
            "model {:?}: the causal decoder serves kind \"lm\", not {:?}",
            info.key,
            info.kind
        );
        let n = info.params.len();
        anyhow::ensure!(
            n >= DEC_EXTRA_PARAMS + DEC_BLOCK_PARAMS
                && (n - DEC_EXTRA_PARAMS) % DEC_BLOCK_PARAMS == 0,
            "model {:?}: {n} params do not form tok/pos + LayerNorm QKV blocks + LN head",
            info.key
        );
        let n_blocks = (n - DEC_EXTRA_PARAMS) / DEC_BLOCK_PARAMS;
        let (tok_name, tok_shape, _) = &info.params[0];
        let (pos_name, pos_shape, _) = &info.params[1];
        anyhow::ensure!(
            tok_name.starts_with("tok_emb") && tok_shape.len() == 2,
            "model {:?}: first param {tok_name:?} {tok_shape:?} is not a token embedding",
            info.key
        );
        let (vocab, d_model) = (tok_shape[0], tok_shape[1]);
        anyhow::ensure!(
            pos_shape.len() == 2 && pos_shape[1] == d_model,
            "model {:?}: position embedding {pos_shape:?} does not match d_model {d_model}",
            info.key
        );
        let max_seq = pos_shape[0];
        let n_heads: usize = if pos_name == "pos_emb" {
            1 // the legacy manifests carry no head tag: single-head
        } else {
            pos_name
                .strip_prefix("pos_emb_h")
                .and_then(|s| s.parse().ok())
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "model {:?}: cannot infer the head count from {pos_name:?} \
                         (expected pos_emb or pos_emb_h<heads>)",
                        info.key
                    )
                })?
        };
        anyhow::ensure!(
            n_heads >= 1 && d_model % n_heads == 0,
            "model {:?}: {n_heads} heads do not divide d_model {d_model}",
            info.key
        );
        // d_ff from the first block's fc1 shape
        let (_, fc1_shape, _) = &info.params[2 + 8];
        anyhow::ensure!(
            fc1_shape.len() == 2 && fc1_shape[0] == d_model,
            "model {:?}: fc1 shape {fc1_shape:?} does not start at d_model {d_model}",
            info.key
        );
        let d_ff = fc1_shape[1];
        let (_, head_shape, _) = &info.params[n - 2];
        anyhow::ensure!(
            head_shape.len() == 2 && head_shape[0] == d_model && head_shape[1] == vocab,
            "model {:?}: head shape {head_shape:?} is not [d_model {d_model}, vocab {vocab}]",
            info.key
        );
        anyhow::ensure!(
            info.n_classes == vocab,
            "model {:?}: n_classes {} != vocab {vocab} (next-token head)",
            info.key,
            info.n_classes
        );
        let dec = Self::new(vocab, d_model, n_heads, d_ff, n_blocks, max_seq);
        // the whole layout (incl. every block + sparse flags) must agree
        let shapes = dec.param_shapes();
        let flags = dec.sparse_flags();
        for (i, (name, shape, sparse)) in info.params.iter().enumerate() {
            anyhow::ensure!(
                *shape == shapes[i],
                "model {:?} param {i} ({name:?}): shape {shape:?} vs expected {:?}",
                info.key,
                shapes[i]
            );
            anyhow::ensure!(
                *sparse == flags[i],
                "model {:?} param {i} ({name:?}): sparse flag {sparse} vs expected {}",
                info.key,
                flags[i]
            );
        }
        Ok(dec)
    }
}

impl super::SparseModel for TokenDecoder {
    fn n_params(&self) -> usize {
        TokenDecoder::n_params(self)
    }

    fn in_dim(&self) -> usize {
        self.max_seq
    }

    fn out_dim(&self) -> usize {
        self.vocab
    }

    fn init(&self, rng: &mut Pcg64) -> Vec<Tensor> {
        TokenDecoder::init(self, rng)
    }

    fn sparse_flags(&self) -> Vec<bool> {
        TokenDecoder::sparse_flags(self)
    }

    fn forward(&self, params: &[Tensor], x: &Tensor) -> Tensor {
        TokenDecoder::forward(self, params, x)
    }

    fn loss_and_grad(&self, params: &[Tensor], x: &Tensor, labels: &[usize]) -> (f64, Vec<Tensor>) {
        TokenDecoder::loss_and_grad(self, params, x, labels)
    }

    fn forward_packed(&self, params: &[PackedParam], x: &Tensor) -> Tensor {
        TokenDecoder::forward_packed(self, params, x)
    }

    fn loss_and_grad_packed_with_cols(
        &self,
        params: &[PackedParam],
        cols: &[Option<Vec<u32>>],
        x: &Tensor,
        labels: &[usize],
    ) -> (f64, Vec<PackedGrad>) {
        assert_eq!(params.len(), self.n_params(), "decoder packed param arity");
        assert_eq!(params.len(), cols.len(), "cols cache arity");
        self.core_loss_and_grad(&WeightsView::Packed { params, cols }, x, labels)
    }

    fn validate_packed_params(&self, params: &[PackedParam]) -> anyhow::Result<()> {
        anyhow::ensure!(
            params.len() == self.n_params(),
            "packed model has {} params, decoder wants {}",
            params.len(),
            self.n_params()
        );
        let shapes = self.param_shapes();
        let flags = self.sparse_flags();
        for (i, p) in params.iter().enumerate() {
            anyhow::ensure!(
                p.shape() == &shapes[i][..],
                "decoder param {i}: shape {:?} vs expected {:?}",
                p.shape(),
                shapes[i]
            );
            if !flags[i] {
                anyhow::ensure!(
                    p.as_dense().is_some(),
                    "decoder param {i} (embedding/norm/bias/head) must be dense"
                );
            }
        }
        Ok(())
    }

    /// Sequences of any length `1..=max_seq` serve (the positional table is
    /// sliced, exactly like the dense forward).
    fn check_input_dim(&self, dim: usize) -> anyhow::Result<()> {
        anyhow::ensure!(
            dim >= 1 && dim <= self.max_seq,
            "batch feature dim {dim} does not fit the decoder (sequence length must be 1..={})",
            self.max_seq
        );
        Ok(())
    }

    /// Value-level validation on top of the width check: every entry must
    /// be a whole in-vocabulary token id — the error twin of the panic the
    /// forward's own `token_ids` gate would raise, so serving rejects a
    /// malformed batch instead of panicking after the counters moved.
    fn validate_input(&self, x: &Tensor) -> anyhow::Result<()> {
        self.check_input_dim(x.last_dim())?;
        for (i, &v) in x.data().iter().enumerate() {
            anyhow::ensure!(
                self.is_token_id(v),
                "batch entry {i} ({v}) is not a token id in vocab 0..{}",
                self.vocab
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::SparseModel;

    fn tiny() -> TokenDecoder {
        TokenDecoder::new(13, 8, 2, 12, 2, 6)
    }

    fn token_batch(rng: &mut Pcg64, dec: &TokenDecoder, bsz: usize, seq: usize) -> Tensor {
        let data: Vec<f32> = (0..bsz * seq).map(|_| rng.below(dec.vocab) as f32).collect();
        Tensor::new(&[bsz, seq], data)
    }

    #[test]
    fn shapes_flags_and_arity() {
        let dec = tiny();
        assert_eq!(dec.n_params(), 6 + 24);
        let shapes = dec.param_shapes();
        assert_eq!(shapes[0], vec![13, 8]);
        assert_eq!(shapes[1], vec![6, 8]);
        assert_eq!(shapes[2], vec![8], "ln1_g");
        assert_eq!(shapes[4], vec![8, 8], "wq");
        assert_eq!(shapes[10], vec![8, 12], "fc1_w");
        let flags = dec.sparse_flags();
        assert_eq!(flags.len(), dec.n_params());
        assert_eq!(flags.iter().filter(|&&f| f).count(), 6 * dec.n_blocks);
        assert!(!flags[0] && !flags[1], "embeddings dense");
        assert!(!flags[2] && !flags[3], "LayerNorm affines dense");
        let names = dec.param_names();
        assert_eq!(names[2], "l0_ln1_g");
        assert_eq!(names[4], "l0_wq");
        assert_eq!(names[dec.n_params() - 2], "head_w");
        let params = dec.init(&mut Pcg64::new(1));
        for (p, s) in params.iter().zip(&shapes) {
            assert_eq!(p.shape(), &s[..]);
        }
    }

    #[test]
    fn init_layer_norm_gains_are_one() {
        let dec = tiny();
        let params = dec.init(&mut Pcg64::new(2));
        let names = dec.param_names();
        for (i, name) in names.iter().enumerate() {
            if name.ends_with("_g") {
                assert!(params[i].data().iter().all(|&v| v == 1.0), "{name}");
            }
            if name.ends_with("ln1_b") || name.ends_with("ln2_b") || name == "lnf_b" {
                assert!(params[i].data().iter().all(|&v| v == 0.0), "{name}");
            }
        }
    }

    #[test]
    fn forward_shapes_and_short_sequences() {
        let dec = tiny();
        let params = dec.init(&mut Pcg64::new(3));
        let mut rng = Pcg64::new(4);
        for seq in [1usize, 3, 6] {
            let x = token_batch(&mut rng, &dec, 4, seq);
            let y = dec.forward(&params, &x);
            assert_eq!(y.shape(), &[4, 13], "seq {seq}");
            assert!(y.data().iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    #[should_panic]
    fn rejects_out_of_vocab_ids() {
        let dec = tiny();
        let params = dec.init(&mut Pcg64::new(5));
        let x = Tensor::new(&[1, 2], vec![0.0, 99.0]);
        dec.forward(&params, &x);
    }

    #[test]
    fn model_info_round_trips_single_and_multi_head() {
        for dec in [TokenDecoder::new(32, 8, 1, 32, 1, 6), tiny()] {
            let info = dec.model_info("dec_rt", 4);
            if dec.n_heads == 1 {
                assert_eq!(info.params[1].0, "pos_emb", "legacy plain name");
            } else {
                assert_eq!(info.params[1].0, "pos_emb_h2");
            }
            let back = TokenDecoder::from_model_info(&info).unwrap();
            assert_eq!(back.vocab, dec.vocab);
            assert_eq!(back.d_model, dec.d_model);
            assert_eq!(back.n_heads, dec.n_heads);
            assert_eq!(back.d_ff, dec.d_ff);
            assert_eq!(back.n_blocks, dec.n_blocks);
            assert_eq!(back.max_seq, dec.max_seq);
        }
    }

    #[test]
    fn decode_matches_full_forward_dense() {
        // teacher-forced decode over a full sequence: the step-t logits
        // must equal forward() over the t+1-token prefix, bit for bit
        let dec = tiny();
        let params = dec.init(&mut Pcg64::new(6));
        let mut rng = Pcg64::new(7);
        let bsz = 3;
        let x = token_batch(&mut rng, &dec, bsz, dec.max_seq);
        let mut cache = dec.new_cache(bsz);
        for t in 0..dec.max_seq {
            let ids: Vec<usize> =
                (0..bsz).map(|r| x.data()[r * dec.max_seq + t] as usize).collect();
            let step = dec.decode_step(&params, &mut cache, &ids).unwrap();
            let prefix = {
                let mut data = Vec::with_capacity(bsz * (t + 1));
                for r in 0..bsz {
                    data.extend_from_slice(&x.data()[r * dec.max_seq..][..t + 1]);
                }
                Tensor::new(&[bsz, t + 1], data)
            };
            let full = dec.forward(&params, &prefix);
            assert_eq!(step.data(), full.data(), "step {t} logits diverge");
        }
        assert_eq!(cache.len(), dec.max_seq);
        let err = dec.decode_step(&params, &mut cache, &vec![0; bsz]);
        assert!(err.is_err(), "decoding past max_seq must error");
    }

    #[test]
    fn cache_eviction_preserves_survivor_bits() {
        let dec = tiny();
        let params = dec.init(&mut Pcg64::new(8));
        let mut rng = Pcg64::new(9);
        let x = token_batch(&mut rng, &dec, 4, 4);
        // advance 4 sequences two steps, evict rows 1 and 3, keep going
        let mut cache = dec.new_cache(4);
        for t in 0..2 {
            let ids: Vec<usize> = (0..4).map(|r| x.data()[r * 4 + t] as usize).collect();
            dec.decode_step(&params, &mut cache, &ids).unwrap();
        }
        cache.evict(&[true, false, true, false]).unwrap();
        assert_eq!(cache.bsz(), 2);
        let ids: Vec<usize> = [0usize, 2].iter().map(|&r| x.data()[r * 4 + 2] as usize).collect();
        let after = dec.decode_step(&params, &mut cache, &ids).unwrap();
        // reference: the same two sequences decoded alone from scratch
        let mut solo = dec.new_cache(2);
        let mut last = None;
        for t in 0..3 {
            let ids: Vec<usize> =
                [0usize, 2].iter().map(|&r| x.data()[r * 4 + t] as usize).collect();
            last = Some(dec.decode_step(&params, &mut solo, &ids).unwrap());
        }
        assert_eq!(after.data(), last.unwrap().data(), "eviction perturbed survivors");
        assert!(cache.evict(&[true]).is_err(), "wrong-arity evict mask must error");
    }

    #[test]
    fn training_reduces_loss() {
        let dec = TokenDecoder::new(9, 8, 2, 12, 1, 5);
        let mut rng = Pcg64::new(10);
        let mut params = dec.init(&mut rng);
        // learnable rule: the next token is the last token plus one mod 9
        let x = token_batch(&mut rng, &dec, 24, 5);
        let labels: Vec<usize> = (0..24)
            .map(|r| (x.data()[r * 5 + 4] as usize + 1) % 9)
            .collect();
        let (first, _) = dec.loss_and_grad(&params, &x, &labels);
        for _ in 0..400 {
            let (_, grads) = dec.loss_and_grad(&params, &x, &labels);
            for (p, g) in params.iter_mut().zip(&grads) {
                crate::tensor::axpy(p, -0.1, g);
            }
        }
        let (last, _) = dec.loss_and_grad(&params, &x, &labels);
        assert!(last < first * 0.5, "{first} -> {last}");
        assert!(dec.accuracy(&params, &x, &labels) > 0.8);
    }
}
