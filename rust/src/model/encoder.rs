//! A pure-Rust single/few-block transformer encoder with **exact** backprop —
//! the paper's central workload family (BERT-Base / GPT-2 train attention
//! models under Adam, §5) brought onto the fast-CPU substrate so the whole
//! STEP pipeline (recipe training → phase switch → pack → packed fine-tune →
//! serve) runs on attention-shaped weight matrices.
//!
//! Architecture per block (no LayerNorm — residual-only, which keeps the
//! backward exactly differentiable with plain f32 kernels):
//!
//! ```text
//!   h   = tok_emb[ids] + pos_emb[0..seq]                  (dense gather)
//!   qkv = h @ W_qkv + b_qkv                               (fused QKV, sparse-eligible)
//!   ctx = softmax(Q Kᵀ / √d_h) V   per head               (exact softmax backprop)
//!   h   = h + ctx @ W_out + b_out                         (sparse-eligible)
//!   h   = h + relu(h @ W_ff1 + b_ff1) @ W_ff2 + b_ff2     (sparse-eligible × 2)
//!   logits = pool(h) @ W_head + b_head                    (dense head)
//! ```
//!
//! All four projection matrices of every block are sparse-eligible;
//! embeddings, biases, and the head stay dense — the transformer analog of
//! the zoo's "hidden weights sparse, head dense" convention (SR-STE /
//! MaskLLM prune exactly this family).
//!
//! **One core, two storage forms.** The forward and backward run through the
//! shared crate-internal `weights::WeightsView` that dispatches each projection
//! matmul to either the dense kernels or the packed N:M kernels
//! ([`crate::sparsity::packed_matmul`] / [`crate::sparsity::packed_matmul_at_into`] /
//! [`crate::sparsity::packed_matmul_bt_into`]).
//! Everything else — embedding gather, softmax, residuals, bias sums — is
//! shared code, so the packed path is **bit-for-bit** identical to the dense
//! *masked* oracle on finite inputs by construction plus the kernel-level
//! equalities the packed engine already guarantees
//! (`rust/tests/token_encoder.rs` holds loss, logits, and every kept
//! gradient coordinate equal).
//!
//! Inputs are token ids carried in an f32 tensor `[batch, seq]` (exact for
//! any realistic vocab; the ids are validated per call), labels are one
//! class per sequence: a GLUE-style classifier pools the first token
//! ([`Pool::First`]), a next-token LM head pools the last ([`Pool::Last`])
//! and classifies over the vocabulary.

use super::weights::{colsum, WeightsView};
use crate::rng::Pcg64;
use crate::runtime::ModelInfo;
use crate::sparsity::dispatch::{self, Dispatch};
use crate::sparsity::{PackedGrad, PackedParam};
use crate::tensor::{add_bias, axpy, cross_entropy_with_grad, Tensor};

/// Parameter tensors per encoder block: `[qkv_w, qkv_b, out_w, out_b,
/// ff1_w, ff1_b, ff2_w, ff2_b]`.
pub const BLOCK_PARAMS: usize = 8;

/// Which position's hidden state feeds the classifier head.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pool {
    /// First token (the CLS convention of the GLUE encoder analogs).
    First,
    /// Last token (next-token prediction: classify over the vocabulary).
    Last,
}

/// A pure-Rust attention encoder implementing [`super::SparseModel`].
#[derive(Debug, Clone)]
pub struct TokenEncoder {
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub n_blocks: usize,
    pub max_seq: usize,
    /// Output width: `n_classes` for classifiers, `vocab` for the
    /// next-token head.
    pub n_out: usize,
    pub pool: Pool,
}

/// Per-block forward caches the backward pass replays.
struct BlockCache {
    /// Block input `[B·S, d]`.
    h_in: Tensor,
    /// Fused QKV activations `[B·S, 3d]`.
    qkv: Tensor,
    /// Attention probabilities, `[B, H, S, S]` row-major.
    probs: Vec<f32>,
    /// Per-head context `[B·S, d]`.
    ctx: Tensor,
    /// Post-attention residual stream `[B·S, d]` (the FFN input).
    h_mid: Tensor,
    /// Post-ReLU FFN hidden `[B·S, d_ff]`.
    ff_r: Tensor,
}

/// The whole forward pass: caches + pooled rows + logits.
struct ForwardPass {
    blocks: Vec<BlockCache>,
    /// Pooled per-sequence rows `[B, d]` (the head input, kept for its
    /// weight gradient).
    pooled: Tensor,
    logits: Tensor,
    /// Validated token ids (reused by the embedding backward so the hot
    /// loop never re-walks the input validation).
    ids: Vec<usize>,
    bsz: usize,
    seq: usize,
}

impl TokenEncoder {
    /// A GLUE-style sequence classifier (first-token pooling).
    #[allow(clippy::too_many_arguments)]
    pub fn classifier(
        vocab: usize,
        d_model: usize,
        n_heads: usize,
        d_ff: usize,
        n_blocks: usize,
        max_seq: usize,
        n_classes: usize,
    ) -> Self {
        Self::build(vocab, d_model, n_heads, d_ff, n_blocks, max_seq, n_classes, Pool::First)
    }

    /// A next-token LM head (last-token pooling, `n_out = vocab`).
    pub fn next_token(
        vocab: usize,
        d_model: usize,
        n_heads: usize,
        d_ff: usize,
        n_blocks: usize,
        max_seq: usize,
    ) -> Self {
        Self::build(vocab, d_model, n_heads, d_ff, n_blocks, max_seq, vocab, Pool::Last)
    }

    #[allow(clippy::too_many_arguments)]
    fn build(
        vocab: usize,
        d_model: usize,
        n_heads: usize,
        d_ff: usize,
        n_blocks: usize,
        max_seq: usize,
        n_out: usize,
        pool: Pool,
    ) -> Self {
        assert!(vocab >= 1 && d_model >= 1 && d_ff >= 1 && n_blocks >= 1 && max_seq >= 1);
        assert!(n_out >= 1, "encoder needs at least one output class");
        assert!(
            n_heads >= 1 && d_model % n_heads == 0,
            "d_model {d_model} must divide into {n_heads} heads"
        );
        Self { vocab, d_model, n_heads, d_ff, n_blocks, max_seq, n_out, pool }
    }

    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub fn n_params(&self) -> usize {
        4 + BLOCK_PARAMS * self.n_blocks
    }

    /// Expected shape of every parameter tensor, in order.
    pub fn param_shapes(&self) -> Vec<Vec<usize>> {
        let d = self.d_model;
        let mut out = Vec::with_capacity(self.n_params());
        out.push(vec![self.vocab, d]);
        out.push(vec![self.max_seq, d]);
        for _ in 0..self.n_blocks {
            out.push(vec![d, 3 * d]);
            out.push(vec![3 * d]);
            out.push(vec![d, d]);
            out.push(vec![d]);
            out.push(vec![d, self.d_ff]);
            out.push(vec![self.d_ff]);
            out.push(vec![self.d_ff, d]);
            out.push(vec![d]);
        }
        out.push(vec![d, self.n_out]);
        out.push(vec![self.n_out]);
        out
    }

    /// Parameter names matching [`param_shapes`](Self::param_shapes) —
    /// `pos_emb_h{heads}` carries the head count so
    /// [`from_model_info`](Self::from_model_info) can round-trip the
    /// architecture from a layout description alone.
    pub fn param_names(&self) -> Vec<String> {
        let mut out = Vec::with_capacity(self.n_params());
        out.push("tok_emb".to_string());
        out.push(format!("pos_emb_h{}", self.n_heads));
        for b in 0..self.n_blocks {
            for suffix in ["qkv_w", "qkv_b", "out_w", "out_b", "ff1_w", "ff1_b", "ff2_w", "ff2_b"]
            {
                out.push(format!("blk{b}_{suffix}"));
            }
        }
        out.push("head_w".to_string());
        out.push("head_b".to_string());
        out
    }

    /// Sparse-eligibility per parameter: the four block projections yes,
    /// embeddings / biases / head no.
    pub fn sparse_flags(&self) -> Vec<bool> {
        let mut out = vec![false, false];
        for _ in 0..self.n_blocks {
            out.extend_from_slice(&[true, false, true, false, true, false, true, false]);
        }
        out.extend_from_slice(&[false, false]);
        out
    }

    /// Fan-in-scaled init (weights ~ N(0, 1/√fan_in), embeddings ~
    /// N(0, 0.05), biases zero), one sequential draw per tensor in layout
    /// order (deterministic in the rng).
    pub fn init(&self, rng: &mut Pcg64) -> Vec<Tensor> {
        self.param_shapes()
            .into_iter()
            .enumerate()
            .map(|(i, shape)| {
                if i < 2 {
                    Tensor::randn(&shape, rng, 0.0, 0.05) // embeddings
                } else if shape.len() == 2 {
                    let scale = 1.0 / (shape[0] as f32).sqrt();
                    Tensor::randn(&shape, rng, 0.0, scale)
                } else {
                    Tensor::zeros(&shape) // biases
                }
            })
            .collect()
    }

    // ---- layout indexing ---------------------------------------------------

    fn i_qkv(&self, b: usize) -> usize {
        2 + BLOCK_PARAMS * b
    }

    fn i_head(&self) -> usize {
        2 + BLOCK_PARAMS * self.n_blocks
    }

    // ---- the shared core ---------------------------------------------------

    /// The single validity rule for an f32-carried token id — shared by the
    /// forward's panic gate ([`token_ids`](Self::token_ids)) and the
    /// serve-time error gate (`validate_input`), so the two can never drift.
    fn is_token_id(&self, v: f32) -> bool {
        v.is_finite() && v >= 0.0 && v.fract() == 0.0 && (v as usize) < self.vocab
    }

    /// Validate and read the token ids out of the f32 input tensor.
    fn token_ids(&self, x: &Tensor) -> (usize, usize, Vec<usize>) {
        let (bsz, seq) = x.as_2d();
        assert!(seq >= 1, "encoder input needs at least one token");
        assert!(
            seq <= self.max_seq,
            "sequence length {seq} exceeds max_seq {}",
            self.max_seq
        );
        let ids: Vec<usize> = x
            .data()
            .iter()
            .map(|&v| {
                assert!(
                    self.is_token_id(v),
                    "token id {v} out of range for vocab {}",
                    self.vocab
                );
                v as usize
            })
            .collect();
        (bsz, seq, ids)
    }

    /// Fused-QKV attention forward for one block: probabilities + context.
    ///
    /// Batched over heads: per query row one [`dispatch::attn_scores_all_heads`]
    /// call scores every head against a transposed key panel and one
    /// [`dispatch::attn_context_all_heads`] call accumulates every head's
    /// context — the SIMD lanes run independent score columns / context
    /// elements, so each accumulator still sees the scalar loop's exact
    /// ascending-`t` / ascending-`j` term order (bit-identity contract).
    fn attention_forward(&self, qkv: &Tensor, bsz: usize, seq: usize) -> (Vec<f32>, Tensor) {
        let d = self.d_model;
        let heads = self.n_heads;
        let dh = self.head_dim();
        let scale = 1.0 / (dh as f32).sqrt();
        let disp = Dispatch::active();
        let qd = qkv.data();
        let mut probs = vec![0f32; bsz * heads * seq * seq];
        let mut ctx = Tensor::zeros(&[bsz * seq, d]);
        let cd = ctx.data_mut();
        // Transposed key panel for one sequence: kt[c][j] = k_j[c]. Pure
        // data movement — values are untouched, so this cannot change bits.
        let mut kt = vec![0f32; d * seq];
        for b in 0..bsz {
            for j in 0..seq {
                let krow = &qd[(b * seq + j) * 3 * d + d..][..d];
                for (c, &v) in krow.iter().enumerate() {
                    kt[c * seq + j] = v;
                }
            }
            for i in 0..seq {
                let qrow = &qd[(b * seq + i) * 3 * d..][..d];
                let pbase = ((b * heads) * seq + i) * seq;
                // scores for all heads of row i: s_hj = (q_h · k_hj) / √d_h
                dispatch::attn_scores_all_heads(
                    disp,
                    qrow,
                    &kt,
                    seq,
                    seq,
                    dh,
                    scale,
                    &mut probs[pbase..],
                    seq * seq,
                );
                for h in 0..heads {
                    let prow = &mut probs[pbase + h * seq * seq..][..seq];
                    // row max, ascending j — same comparisons as the scalar
                    // inline tracking
                    let mut mx = f32::NEG_INFINITY;
                    for &p in prow.iter() {
                        if p > mx {
                            mx = p;
                        }
                    }
                    // exact softmax: e_j = exp(s_j − max), p_j = e_j / Σe
                    let mut denom = 0f64;
                    for p in prow.iter_mut() {
                        let e = ((*p - mx) as f64).exp();
                        *p = e as f32;
                        denom += e;
                    }
                    for p in prow.iter_mut() {
                        *p = ((*p as f64) / denom) as f32;
                    }
                }
                // ctx_i = Σ_j p_ij · v_j for every head in one call
                let crow = &mut cd[(b * seq + i) * d..][..d];
                dispatch::attn_context_all_heads(
                    disp,
                    &probs[pbase..],
                    seq * seq,
                    seq,
                    &qd[(b * seq) * 3 * d + 2 * d..],
                    3 * d,
                    dh,
                    crow,
                );
            }
        }
        (probs, ctx)
    }

    /// Exact attention backward: `d_qkv` from `d_ctx`, the stored
    /// probabilities and the forward QKV activations. The softmax Jacobian
    /// is applied in closed form: `ds = p ⊙ (dp − Σ_j p_j dp_j)`.
    fn attention_backward(
        &self,
        qkv: &Tensor,
        probs: &[f32],
        d_ctx: &Tensor,
        bsz: usize,
        seq: usize,
    ) -> Tensor {
        let d = self.d_model;
        let heads = self.n_heads;
        let dh = self.head_dim();
        let scale = 1.0 / (dh as f32).sqrt();
        let qd = qkv.data();
        let dcd = d_ctx.data();
        let mut d_qkv = Tensor::zeros(&[bsz * seq, 3 * d]);
        let dqd = d_qkv.data_mut();
        let mut dp = vec![0f32; seq];
        for b in 0..bsz {
            for h in 0..heads {
                let col = h * dh;
                for i in 0..seq {
                    let prow = &probs[((b * heads + h) * seq + i) * seq..][..seq];
                    let dcrow = &dcd[(b * seq + i) * d + col..][..dh];
                    // dV_j += p_ij · dctx_i ; dp_ij = dctx_i · v_j
                    for (j, &p) in prow.iter().enumerate() {
                        let vrow = &qd[(b * seq + j) * 3 * d + 2 * d + col..][..dh];
                        let dvrow = &mut dqd[(b * seq + j) * 3 * d + 2 * d + col..][..dh];
                        let mut acc = 0f32;
                        for t in 0..dh {
                            acc += dcrow[t] * vrow[t];
                            dvrow[t] += p * dcrow[t];
                        }
                        dp[j] = acc;
                    }
                    // softmax Jacobian row: ds = p ⊙ (dp − Σ p·dp)
                    let mut inner = 0f64;
                    for (&p, &g) in prow.iter().zip(dp.iter()) {
                        inner += (p as f64) * (g as f64);
                    }
                    let inner = inner as f32;
                    // dQ_i += Σ_j ds_ij K_j · scale ; dK_j += ds_ij Q_i · scale
                    let qrow = &qd[(b * seq + i) * 3 * d + col..][..dh];
                    for j in 0..seq {
                        let ds = prow[j] * (dp[j] - inner) * scale;
                        if ds == 0.0 {
                            continue; // zero rows add exact zeros on both paths
                        }
                        let krow = &qd[(b * seq + j) * 3 * d + d + col..][..dh];
                        let dkrow = &mut dqd[(b * seq + j) * 3 * d + d + col..][..dh];
                        for t in 0..dh {
                            dkrow[t] += ds * qrow[t];
                        }
                        let dqrow = &mut dqd[(b * seq + i) * 3 * d + col..][..dh];
                        for t in 0..dh {
                            dqrow[t] += ds * krow[t];
                        }
                    }
                }
            }
        }
        d_qkv
    }

    /// The full forward pass with caches (shared by inference and training;
    /// the storage form only changes which matmul kernels run).
    fn run_forward(&self, w: &WeightsView, x: &Tensor) -> ForwardPass {
        let (bsz, seq, ids) = self.token_ids(x);
        let d = self.d_model;
        // embed: tok[id] + pos[s]
        let tok = w.tensor(0);
        let pos = w.tensor(1);
        let mut h = Tensor::zeros(&[bsz * seq, d]);
        {
            let td = tok.data();
            let pd = pos.data();
            let hd = h.data_mut();
            for r in 0..bsz {
                for s in 0..seq {
                    let id = ids[r * seq + s];
                    let row = &mut hd[(r * seq + s) * d..][..d];
                    let trow = &td[id * d..][..d];
                    let prow = &pd[s * d..][..d];
                    for j in 0..d {
                        row[j] = trow[j] + prow[j];
                    }
                }
            }
        }
        let mut blocks = Vec::with_capacity(self.n_blocks);
        for blk in 0..self.n_blocks {
            let i = self.i_qkv(blk);
            let mut qkv = w.matmul(&h, i);
            add_bias(&mut qkv, w.tensor(i + 1));
            let (probs, ctx) = self.attention_forward(&qkv, bsz, seq);
            let mut attn_out = w.matmul(&ctx, i + 2);
            add_bias(&mut attn_out, w.tensor(i + 3));
            let mut h_mid = h.clone();
            axpy(&mut h_mid, 1.0, &attn_out);
            let mut ff = w.matmul(&h_mid, i + 4);
            add_bias(&mut ff, w.tensor(i + 5));
            let ff_r = crate::tensor::relu(&ff);
            let mut ff_out = w.matmul(&ff_r, i + 6);
            add_bias(&mut ff_out, w.tensor(i + 7));
            let mut h_out = h_mid.clone();
            axpy(&mut h_out, 1.0, &ff_out);
            blocks.push(BlockCache { h_in: h, qkv, probs, ctx, h_mid, ff_r });
            h = h_out;
        }
        // pool one position per sequence, then the dense head
        let pool_pos = match self.pool {
            Pool::First => 0,
            Pool::Last => seq - 1,
        };
        let mut pooled = Tensor::zeros(&[bsz, d]);
        {
            let hd = h.data();
            let pd = pooled.data_mut();
            for r in 0..bsz {
                pd[r * d..(r + 1) * d]
                    .copy_from_slice(&hd[(r * seq + pool_pos) * d..][..d]);
            }
        }
        let ih = self.i_head();
        let mut logits = w.matmul(&pooled, ih);
        add_bias(&mut logits, w.tensor(ih + 1));
        ForwardPass { blocks, pooled, logits, ids, bsz, seq }
    }

    /// Loss + gradients through the shared core; the grad of parameter `i`
    /// is compact exactly when `w` stores it packed.
    fn core_loss_and_grad(
        &self,
        w: &WeightsView,
        x: &Tensor,
        labels: &[usize],
    ) -> (f64, Vec<PackedGrad>) {
        let fwd = self.run_forward(w, x);
        let (bsz, seq) = (fwd.bsz, fwd.seq);
        let d = self.d_model;
        let (loss, dlogits) = cross_entropy_with_grad(&fwd.logits, labels);

        let mut grads: Vec<PackedGrad> = (0..self.n_params())
            .map(|_| PackedGrad::Dense(Tensor::zeros(&[0])))
            .collect();

        // head
        let ih = self.i_head();
        grads[ih] = w.grad_w(&fwd.pooled, &dlogits, ih);
        grads[ih + 1] = PackedGrad::Dense(colsum(&dlogits));
        let dpooled = w.matmul_bt(&dlogits, ih);

        // scatter the pooled gradient back into the residual stream
        let pool_pos = match self.pool {
            Pool::First => 0,
            Pool::Last => seq - 1,
        };
        let mut dh = Tensor::zeros(&[bsz * seq, d]);
        {
            let dpd = dpooled.data();
            let dhd = dh.data_mut();
            for r in 0..bsz {
                dhd[(r * seq + pool_pos) * d..][..d]
                    .copy_from_slice(&dpd[r * d..(r + 1) * d]);
            }
        }

        for blk in (0..self.n_blocks).rev() {
            let cache = &fwd.blocks[blk];
            let i = self.i_qkv(blk);
            // ---- FFN backward (residual: h_out = h_mid + ffn(h_mid)) ----
            grads[i + 6] = w.grad_w(&cache.ff_r, &dh, i + 6);
            grads[i + 7] = PackedGrad::Dense(colsum(&dh));
            let mut dr = w.matmul_bt(&dh, i + 6);
            for (g, &r) in dr.data_mut().iter_mut().zip(cache.ff_r.data()) {
                if r <= 0.0 {
                    *g = 0.0; // ReLU gate, same convention as the MLP
                }
            }
            grads[i + 4] = w.grad_w(&cache.h_mid, &dr, i + 4);
            grads[i + 5] = PackedGrad::Dense(colsum(&dr));
            let mut dh_mid = dh; // the residual passes dh through unchanged
            axpy(&mut dh_mid, 1.0, &w.matmul_bt(&dr, i + 4));

            // ---- attention backward (residual: h_mid = h_in + attn) ----
            grads[i + 2] = w.grad_w(&cache.ctx, &dh_mid, i + 2);
            grads[i + 3] = PackedGrad::Dense(colsum(&dh_mid));
            let dctx = w.matmul_bt(&dh_mid, i + 2);
            let dqkv = self.attention_backward(&cache.qkv, &cache.probs, &dctx, bsz, seq);
            grads[i] = w.grad_w(&cache.h_in, &dqkv, i);
            grads[i + 1] = PackedGrad::Dense(colsum(&dqkv));
            let mut dh_in = dh_mid;
            axpy(&mut dh_in, 1.0, &w.matmul_bt(&dqkv, i));
            dh = dh_in;
        }

        // embeddings: scatter-add per token id / position (ids validated
        // once by the forward pass)
        let ids = &fwd.ids;
        let mut dtok = Tensor::zeros(&[self.vocab, d]);
        let mut dpos = Tensor::zeros(&[self.max_seq, d]);
        {
            let dhd = dh.data();
            let dtd = dtok.data_mut();
            let dpd = dpos.data_mut();
            for r in 0..bsz {
                for s in 0..seq {
                    let row = &dhd[(r * seq + s) * d..][..d];
                    let id = ids[r * seq + s];
                    let trow = &mut dtd[id * d..][..d];
                    for j in 0..d {
                        trow[j] += row[j];
                    }
                    let prow = &mut dpd[s * d..][..d];
                    for j in 0..d {
                        prow[j] += row[j];
                    }
                }
            }
        }
        grads[0] = PackedGrad::Dense(dtok);
        grads[1] = PackedGrad::Dense(dpos);
        (loss, grads)
    }

    // ---- inherent conveniences (the trait impl delegates here) -----------

    /// Dense forward: logits `[batch, n_out]` from token ids `[batch, seq]`.
    pub fn forward(&self, params: &[Tensor], x: &Tensor) -> Tensor {
        assert_eq!(params.len(), self.n_params(), "encoder param arity");
        self.run_forward(&WeightsView::Dense(params), x).logits
    }

    /// Packed forward — bit-identical to [`forward`](Self::forward) over
    /// the dense masked weights on finite inputs.
    pub fn forward_packed(&self, params: &[PackedParam], x: &Tensor) -> Tensor {
        assert_eq!(params.len(), self.n_params(), "encoder packed param arity");
        let cols: Vec<Option<Vec<u32>>> = vec![None; params.len()];
        self.run_forward(&WeightsView::Packed { params, cols: &cols }, x)
            .logits
    }

    /// Dense loss + exact gradients.
    pub fn loss_and_grad(
        &self,
        params: &[Tensor],
        x: &Tensor,
        labels: &[usize],
    ) -> (f64, Vec<Tensor>) {
        assert_eq!(params.len(), self.n_params(), "encoder param arity");
        let (loss, grads) = self.core_loss_and_grad(&WeightsView::Dense(params), x, labels);
        let grads = grads
            .into_iter()
            .map(|g| match g {
                PackedGrad::Dense(t) => t,
                // nm-lint: allow(panic-freedom): core_loss_and_grad returns Compact only for packed views; this branch is the Dense view
                PackedGrad::Compact(_) => unreachable!("dense path yields dense grads"),
            })
            .collect();
        (loss, grads)
    }

    /// Describe this encoder as a manifest-style [`ModelInfo`]; the layout
    /// (names + shapes) is sufficient to rebuild the architecture via
    /// [`from_model_info`](Self::from_model_info).
    pub fn model_info(&self, key: &str, batch: usize) -> ModelInfo {
        let names = self.param_names();
        let shapes = self.param_shapes();
        let flags = self.sparse_flags();
        let params: Vec<(String, Vec<usize>, bool)> = names
            .into_iter()
            .zip(shapes)
            .zip(flags.iter().copied())
            .map(|((n, s), f)| (n, s, f))
            .collect();
        let sparse_indices = flags
            .iter()
            .enumerate()
            .filter_map(|(i, &s)| s.then_some(i))
            .collect();
        let dim = params.iter().map(|(_, s, _)| s.iter().product::<usize>()).sum();
        ModelInfo {
            key: key.to_string(),
            params,
            sparse_indices,
            kind: match self.pool {
                Pool::First => "classify".to_string(),
                Pool::Last => "lm".to_string(),
            },
            n_classes: self.n_out,
            dim,
            batch,
            seq: Some(self.max_seq),
        }
    }

    /// Rebuild a [`TokenEncoder`] from a manifest layout written by
    /// [`model_info`](Self::model_info): `tok_emb`/`pos_emb_h{heads}`
    /// followed by fused-QKV blocks and a dense head. Kind `"lm"` pools the
    /// last token (next-token head), anything else pools the first.
    pub fn from_model_info(info: &ModelInfo) -> anyhow::Result<Self> {
        anyhow::ensure!(
            info.kind == "classify" || info.kind == "lm",
            "model {:?}: the pure-Rust encoder serves classify/lm kinds, not {:?}",
            info.key,
            info.kind
        );
        let n = info.params.len();
        anyhow::ensure!(
            n >= 4 + BLOCK_PARAMS && (n - 4) % BLOCK_PARAMS == 0,
            "model {:?}: {n} params do not form tok/pos + QKV blocks + head",
            info.key
        );
        let n_blocks = (n - 4) / BLOCK_PARAMS;
        let (tok_name, tok_shape, _) = &info.params[0];
        let (pos_name, pos_shape, _) = &info.params[1];
        anyhow::ensure!(
            tok_name.starts_with("tok_emb") && tok_shape.len() == 2,
            "model {:?}: first param {tok_name:?} {tok_shape:?} is not a token embedding",
            info.key
        );
        let (vocab, d_model) = (tok_shape[0], tok_shape[1]);
        anyhow::ensure!(
            pos_shape.len() == 2 && pos_shape[1] == d_model,
            "model {:?}: position embedding {pos_shape:?} does not match d_model {d_model}",
            info.key
        );
        let max_seq = pos_shape[0];
        let n_heads: usize = pos_name
            .strip_prefix("pos_emb_h")
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "model {:?}: cannot infer the head count from {pos_name:?} \
                     (expected pos_emb_h<heads>)",
                    info.key
                )
            })?;
        anyhow::ensure!(
            n_heads >= 1 && d_model % n_heads == 0,
            "model {:?}: {n_heads} heads do not divide d_model {d_model}",
            info.key
        );
        // d_ff from the first block's ff1 shape
        let (_, ff1_shape, _) = &info.params[2 + 4];
        anyhow::ensure!(
            ff1_shape.len() == 2 && ff1_shape[0] == d_model,
            "model {:?}: ff1 shape {ff1_shape:?} does not start at d_model {d_model}",
            info.key
        );
        let d_ff = ff1_shape[1];
        let (_, head_shape, _) = &info.params[n - 2];
        anyhow::ensure!(
            head_shape.len() == 2 && head_shape[0] == d_model,
            "model {:?}: head shape {head_shape:?} does not start at d_model {d_model}",
            info.key
        );
        let n_out = head_shape[1];
        anyhow::ensure!(
            n_out == info.n_classes,
            "model {:?}: head fan-out {n_out} != n_classes {}",
            info.key,
            info.n_classes
        );
        let pool = if info.kind == "lm" { Pool::Last } else { Pool::First };
        let enc = Self::build(vocab, d_model, n_heads, d_ff, n_blocks, max_seq, n_out, pool);
        // the whole layout (incl. every block + sparse flags) must agree
        let shapes = enc.param_shapes();
        let flags = enc.sparse_flags();
        for (i, (name, shape, sparse)) in info.params.iter().enumerate() {
            anyhow::ensure!(
                *shape == shapes[i],
                "model {:?} param {i} ({name:?}): shape {shape:?} vs expected {:?}",
                info.key,
                shapes[i]
            );
            anyhow::ensure!(
                *sparse == flags[i],
                "model {:?} param {i} ({name:?}): sparse flag {sparse} vs expected {}",
                info.key,
                flags[i]
            );
        }
        Ok(enc)
    }
}

impl super::SparseModel for TokenEncoder {
    fn n_params(&self) -> usize {
        TokenEncoder::n_params(self)
    }

    fn in_dim(&self) -> usize {
        self.max_seq
    }

    fn out_dim(&self) -> usize {
        self.n_out
    }

    fn init(&self, rng: &mut Pcg64) -> Vec<Tensor> {
        TokenEncoder::init(self, rng)
    }

    fn sparse_flags(&self) -> Vec<bool> {
        TokenEncoder::sparse_flags(self)
    }

    fn forward(&self, params: &[Tensor], x: &Tensor) -> Tensor {
        TokenEncoder::forward(self, params, x)
    }

    fn loss_and_grad(&self, params: &[Tensor], x: &Tensor, labels: &[usize]) -> (f64, Vec<Tensor>) {
        TokenEncoder::loss_and_grad(self, params, x, labels)
    }

    fn forward_packed(&self, params: &[PackedParam], x: &Tensor) -> Tensor {
        TokenEncoder::forward_packed(self, params, x)
    }

    fn loss_and_grad_packed_with_cols(
        &self,
        params: &[PackedParam],
        cols: &[Option<Vec<u32>>],
        x: &Tensor,
        labels: &[usize],
    ) -> (f64, Vec<PackedGrad>) {
        assert_eq!(params.len(), self.n_params(), "encoder packed param arity");
        assert_eq!(params.len(), cols.len(), "cols cache arity");
        self.core_loss_and_grad(&WeightsView::Packed { params, cols }, x, labels)
    }

    fn validate_packed_params(&self, params: &[PackedParam]) -> anyhow::Result<()> {
        anyhow::ensure!(
            params.len() == self.n_params(),
            "packed model has {} params, encoder wants {}",
            params.len(),
            self.n_params()
        );
        let shapes = self.param_shapes();
        let flags = self.sparse_flags();
        for (i, p) in params.iter().enumerate() {
            anyhow::ensure!(
                p.shape() == &shapes[i][..],
                "encoder param {i}: shape {:?} vs expected {:?}",
                p.shape(),
                shapes[i]
            );
            if !flags[i] {
                anyhow::ensure!(
                    p.as_dense().is_some(),
                    "encoder param {i} (embedding/bias/head) must be dense"
                );
            }
        }
        Ok(())
    }

    /// Sequences of any length `1..=max_seq` serve (the positional table is
    /// sliced, exactly like the dense forward).
    fn check_input_dim(&self, dim: usize) -> anyhow::Result<()> {
        anyhow::ensure!(
            dim >= 1 && dim <= self.max_seq,
            "batch feature dim {dim} does not fit the encoder (sequence length must be 1..={})",
            self.max_seq
        );
        Ok(())
    }

    /// Value-level validation on top of the width check: every entry must
    /// be a whole in-vocabulary token id — the error twin of the panic the
    /// forward's own `token_ids` gate would raise, so serving rejects a
    /// malformed batch instead of panicking after the counters moved.
    fn validate_input(&self, x: &Tensor) -> anyhow::Result<()> {
        self.check_input_dim(x.last_dim())?;
        for (i, &v) in x.data().iter().enumerate() {
            anyhow::ensure!(
                self.is_token_id(v),
                "batch entry {i} ({v}) is not a token id in vocab 0..{}",
                self.vocab
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::SparseModel;

    fn tiny() -> TokenEncoder {
        TokenEncoder::classifier(11, 8, 2, 12, 2, 6, 3)
    }

    fn token_batch(rng: &mut Pcg64, enc: &TokenEncoder, bsz: usize, seq: usize) -> Tensor {
        let data: Vec<f32> = (0..bsz * seq).map(|_| rng.below(enc.vocab) as f32).collect();
        Tensor::new(&[bsz, seq], data)
    }

    #[test]
    fn shapes_flags_and_arity() {
        let enc = tiny();
        assert_eq!(enc.n_params(), 4 + 16);
        let shapes = enc.param_shapes();
        assert_eq!(shapes[0], vec![11, 8]);
        assert_eq!(shapes[2], vec![8, 24], "fused QKV");
        let flags = enc.sparse_flags();
        assert_eq!(flags.len(), enc.n_params());
        assert_eq!(flags.iter().filter(|&&f| f).count(), 4 * enc.n_blocks);
        assert!(!flags[0] && !flags[1], "embeddings dense");
        assert!(!flags[enc.n_params() - 1] && !flags[enc.n_params() - 2], "head dense");
        let params = enc.init(&mut Pcg64::new(1));
        for (p, s) in params.iter().zip(&shapes) {
            assert_eq!(p.shape(), &s[..]);
        }
    }

    #[test]
    fn forward_shapes_and_short_sequences() {
        let enc = tiny();
        let params = enc.init(&mut Pcg64::new(2));
        let mut rng = Pcg64::new(3);
        for seq in [1usize, 3, 6] {
            let x = token_batch(&mut rng, &enc, 4, seq);
            let y = enc.forward(&params, &x);
            assert_eq!(y.shape(), &[4, 3], "seq {seq}");
            assert!(y.data().iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    #[should_panic]
    fn rejects_out_of_vocab_ids() {
        let enc = tiny();
        let params = enc.init(&mut Pcg64::new(4));
        let x = Tensor::new(&[1, 2], vec![0.0, 99.0]);
        enc.forward(&params, &x);
    }

    #[test]
    fn pooling_selects_the_configured_position() {
        // two inputs differing only at the last position must give different
        // logits under Pool::Last... and identical logits when every block's
        // attention output is what carries the difference is hard to pin —
        // instead check First vs Last on a 1-block encoder directly.
        let first = TokenEncoder::classifier(7, 4, 1, 6, 1, 4, 2);
        let last = TokenEncoder { pool: Pool::Last, ..first.clone() };
        let params = first.init(&mut Pcg64::new(5));
        let x = Tensor::new(&[1, 4], vec![1.0, 2.0, 3.0, 4.0]);
        let yf = first.forward(&params, &x);
        let yl = last.forward(&params, &x);
        assert_ne!(yf.data(), yl.data(), "pooling position must matter");
    }

    #[test]
    fn model_info_round_trips_classifier_and_lm() {
        for enc in [tiny(), TokenEncoder::next_token(16, 8, 4, 8, 1, 5)] {
            let info = enc.model_info("enc_rt", 4);
            let back = TokenEncoder::from_model_info(&info).unwrap();
            assert_eq!(back.vocab, enc.vocab);
            assert_eq!(back.d_model, enc.d_model);
            assert_eq!(back.n_heads, enc.n_heads);
            assert_eq!(back.d_ff, enc.d_ff);
            assert_eq!(back.n_blocks, enc.n_blocks);
            assert_eq!(back.max_seq, enc.max_seq);
            assert_eq!(back.n_out, enc.n_out);
            assert_eq!(back.pool, enc.pool);
        }
    }

    #[test]
    fn training_reduces_loss() {
        let enc = TokenEncoder::classifier(9, 8, 2, 12, 1, 5, 3);
        let mut rng = Pcg64::new(7);
        let mut params = enc.init(&mut rng);
        // learnable rule: the class is the first token modulo 3
        let x = token_batch(&mut rng, &enc, 24, 5);
        let labels: Vec<usize> = (0..24)
            .map(|r| x.data()[r * 5] as usize % 3)
            .collect();
        let (first, _) = enc.loss_and_grad(&params, &x, &labels);
        for _ in 0..400 {
            let (_, grads) = enc.loss_and_grad(&params, &x, &labels);
            for (p, g) in params.iter_mut().zip(&grads) {
                crate::tensor::axpy(p, -0.1, g);
            }
        }
        let (last, _) = enc.loss_and_grad(&params, &x, &labels);
        assert!(last < first * 0.5, "{first} -> {last}");
        assert!(enc.accuracy(&params, &x, &labels) > 0.8);
    }
}
