//! Pure-Rust MLP with exact backprop — the fast-CPU substrate for the
//! many-seed / many-step experiments (Table 1 traces, Theorem-1 checks,
//! Fig. 7's switch-ratio sweep) where per-step PJRT dispatch would dominate.
//!
//! The layout mirrors `python/compile/models.mlp`: parameters are the flat
//! ordered list `[fc0_w, fc0_b, fc1_w, fc1_b, …]` with hidden weight
//! matrices sparse-eligible and the final layer dense, so recipe code (and
//! the manifest conventions) transfer unchanged between the two engines.
//!
//! [`Mlp`] keeps its full inherent API (every pre-trait call site compiles
//! unchanged) and additionally implements [`super::SparseModel`] by
//! delegation, so the model-generic coordinator layers
//! ([`BatchServer`](crate::coordinator::serve::BatchServer),
//! [`FinetuneSession`](crate::coordinator::finetune::FinetuneSession),
//! [`TrainDriver`](crate::coordinator::driver::TrainDriver)) drive it
//! through the same entry points as the token models.

use crate::rng::Pcg64;
use crate::runtime::ModelInfo;
use crate::sparsity::{
    packed_matmul_at_into, packed_matmul_bt_tiled_into, packed_matmul_rows_into, NmRatio,
    PackedGrad, PackedParam, PackedScratch,
};
use crate::tensor::{
    accuracy_from_logits, add_bias, cross_entropy_with_grad, matmul, matmul_at, matmul_bt,
    matmul_rows, relu, Tensor,
};

/// An MLP classifier: `in_dim → hidden… → n_classes`, ReLU activations.
#[derive(Debug, Clone)]
pub struct Mlp {
    pub sizes: Vec<usize>,
}

impl Mlp {
    pub fn new(in_dim: usize, hidden: &[usize], n_classes: usize) -> Self {
        let mut sizes = vec![in_dim];
        sizes.extend_from_slice(hidden);
        sizes.push(n_classes);
        Self { sizes }
    }

    pub fn n_layers(&self) -> usize {
        self.sizes.len() - 1
    }

    /// Number of parameter tensors (2 per layer: weight, bias).
    pub fn n_params(&self) -> usize {
        2 * self.n_layers()
    }

    /// Total scalar parameter count.
    pub fn dim(&self) -> usize {
        self.init(&mut Pcg64::new(0)).iter().map(|t| t.numel()).sum()
    }

    /// Fan-in-scaled init matching `models._init_param` (weights ~
    /// N(0, 1/fan_in), biases zero).
    pub fn init(&self, rng: &mut Pcg64) -> Vec<Tensor> {
        let mut out = Vec::with_capacity(self.n_params());
        for l in 0..self.n_layers() {
            let (fan_in, fan_out) = (self.sizes[l], self.sizes[l + 1]);
            let scale = 1.0 / (fan_in as f32).sqrt();
            out.push(Tensor::randn(&[fan_in, fan_out], rng, 0.0, scale));
            out.push(Tensor::zeros(&[fan_out]));
        }
        out
    }

    /// Sparse-eligibility per parameter tensor: hidden weights yes, last
    /// layer and biases no — matching the Python model zoo.
    pub fn sparse_flags(&self) -> Vec<bool> {
        let n = self.n_layers();
        (0..self.n_params())
            .map(|i| i % 2 == 0 && i / 2 != n - 1)
            .collect()
    }

    /// Uniform ratio vector from the flags (`None` = dense tensor).
    pub fn ratios(&self, ratio: NmRatio) -> Vec<Option<NmRatio>> {
        self.sparse_flags()
            .into_iter()
            .map(|s| if s { Some(ratio) } else { None })
            .collect()
    }

    /// Forward pass: logits `[batch, n_classes]`.
    pub fn forward(&self, params: &[Tensor], x: &Tensor) -> Tensor {
        let reshaped;
        let x2d: &Tensor = if x.ndim() == 2 {
            x // layer 0 only reads its input — no defensive copy
        } else {
            reshaped = x.clone().reshape(&[x.rows_2d(), x.last_dim()]);
            &reshaped
        };
        // peel layer 0 so the accumulator is never empty (no Option, no
        // panic path) — op order is identical to the fused loop
        let mut h = matmul(x2d, &params[0]);
        add_bias(&mut h, &params[1]);
        if self.n_layers() > 1 {
            h = relu(&h);
        }
        for l in 1..self.n_layers() {
            let mut next = matmul(&h, &params[2 * l]);
            add_bias(&mut next, &params[2 * l + 1]);
            if l != self.n_layers() - 1 {
                next = relu(&next);
            }
            h = next;
        }
        h
    }

    /// Forward pass over **packed** weights: logits `[batch, n_classes]`.
    ///
    /// The inference twin of [`Mlp::forward`]: hidden weights stored as
    /// [`PackedNmTensor`](crate::sparsity::PackedNmTensor) run the sparse
    /// kernels ([`crate::sparsity::packed_matmul_rows_into`]) that skip
    /// pruned slots, dense parameters
    /// run the ordinary dense path. Output is bit-for-bit identical to
    /// `forward` over the dense *masked* weights on finite inputs — the
    /// integration suite (`rust/tests/packed_inference.rs`) holds the two
    /// equal across batch sizes.
    pub fn forward_packed(&self, params: &[PackedParam], x: &Tensor) -> Tensor {
        assert_eq!(
            x.last_dim(),
            self.sizes[0],
            "input feature dim {} vs model input dim {}",
            x.last_dim(),
            self.sizes[0]
        );
        self.forward_packed_rows(params, x.data(), x.rows_2d())
    }

    /// Packed forward pass over a **borrowed** row-major slice of `rows`
    /// samples (`sizes[0]` features each) — the copy-free entry the
    /// threaded [`BatchServer`](crate::coordinator::serve::BatchServer)
    /// shards call so no per-shard input tensor is ever materialized.
    /// [`Mlp::forward_packed`] delegates here.
    pub fn forward_packed_rows(&self, params: &[PackedParam], xs: &[f32], rows: usize) -> Tensor {
        assert_eq!(params.len(), self.n_params(), "packed param arity");
        assert_eq!(
            xs.len(),
            rows * self.sizes[0],
            "input slice {} vs {rows}x{}",
            xs.len(),
            self.sizes[0]
        );
        // One scratch threads through every packed layer, so a steady-state
        // forward is allocation-free in the kernels (the per-layer
        // activation tensors remain; they are the function's output chain).
        let mut scratch = PackedScratch::new();
        // layer 0 reads straight from the borrowed slice
        // nm-lint: allow(panic-freedom): validate_packed_params at server construction guarantees dense biases
        let b0 = params[1].as_dense().expect("bias tensors are never packed");
        let mut h = Tensor::zeros(&[rows, self.sizes[1]]);
        match &params[0] {
            PackedParam::Dense(w) => matmul_rows(xs, rows, self.sizes[0], w, &mut h),
            PackedParam::Packed(w) => packed_matmul_rows_into(xs, rows, w, &mut h, &mut scratch),
        }
        add_bias(&mut h, b0);
        if self.n_layers() > 1 {
            h = relu(&h);
        }
        for l in 1..self.n_layers() {
            let b = params[2 * l + 1]
                .as_dense()
                // nm-lint: allow(panic-freedom): validate_packed_params at server construction guarantees dense biases
                .expect("bias tensors are never packed");
            let mut next = match &params[2 * l] {
                PackedParam::Dense(w) => matmul(&h, w),
                PackedParam::Packed(w) => {
                    let mut c = Tensor::zeros(&[rows, self.sizes[l + 1]]);
                    packed_matmul_rows_into(h.data(), rows, w, &mut c, &mut scratch);
                    c
                }
            };
            add_bias(&mut next, b);
            if l != self.n_layers() - 1 {
                next = relu(&next);
            }
            h = next;
        }
        h
    }

    /// Validate a packed parameter list against this MLP's `[w, b, …]`
    /// layout (arity, weight shapes, dense biases) — the single layout
    /// check shared by [`BatchServer`](crate::coordinator::serve::BatchServer)
    /// and [`FinetuneSession`](crate::coordinator::finetune::FinetuneSession)
    /// construction.
    pub fn validate_packed_params(&self, params: &[PackedParam]) -> anyhow::Result<()> {
        anyhow::ensure!(
            params.len() == self.n_params(),
            "packed model has {} params, MLP wants {}",
            params.len(),
            self.n_params()
        );
        for l in 0..self.n_layers() {
            let (fan_in, fan_out) = (self.sizes[l], self.sizes[l + 1]);
            anyhow::ensure!(
                params[2 * l].shape() == &[fan_in, fan_out],
                "layer {l} weight shape {:?} vs [{fan_in}, {fan_out}]",
                params[2 * l].shape()
            );
            anyhow::ensure!(
                params[2 * l + 1].as_dense().is_some()
                    && params[2 * l + 1].shape() == &[fan_out],
                "layer {l} bias must be dense [{fan_out}]"
            );
        }
        Ok(())
    }

    /// The dense **masked** parameter list: `Π ⊙ w` on sparse-eligible
    /// tensors (via [`crate::sparsity::apply_nm`]), clones elsewhere — the
    /// baseline every packed path is held bit-identical to.
    pub fn masked_params(&self, params: &[Tensor], ratio: NmRatio) -> Vec<Tensor> {
        params
            .iter()
            .zip(self.sparse_flags())
            .map(|(p, s)| if s { crate::sparsity::apply_nm(p, ratio) } else { p.clone() })
            .collect()
    }

    /// Pack trained parameters for inference: hidden weights are compressed
    /// at `ratio` (the same selection rule training masks used), biases and
    /// the final layer stay dense. The one-time export step before serving —
    /// see [`crate::coordinator::serve::BatchServer`].
    pub fn pack_params(&self, params: &[Tensor], ratio: NmRatio) -> Vec<PackedParam> {
        crate::sparsity::pack_params(params, &self.ratios(ratio))
    }

    /// Classification accuracy of a packed model on a batch.
    pub fn accuracy_packed(&self, params: &[PackedParam], x: &Tensor, labels: &[usize]) -> f64 {
        accuracy_from_logits(&self.forward_packed(params, x), labels)
    }

    /// Mean cross-entropy loss + exact gradients w.r.t. every parameter.
    ///
    /// Returns `(loss, grads)` where `grads[i]` matches `params[i]`'s shape.
    pub fn loss_and_grad(
        &self,
        params: &[Tensor],
        x: &Tensor,
        labels: &[usize],
    ) -> (f64, Vec<Tensor>) {
        let n_layers = self.n_layers();
        let reshaped;
        let x2d: &Tensor = if x.ndim() == 2 {
            x // layer 0 only reads its input — no defensive copy
        } else {
            reshaped = x.clone().reshape(&[x.rows_2d(), x.last_dim()]);
            &reshaped
        };
        // forward, caching each layer's post-ReLU output
        let mut acts: Vec<Tensor> = Vec::with_capacity(n_layers);
        for l in 0..n_layers {
            let input = if l == 0 { x2d } else { &acts[l - 1] };
            let mut h = matmul(input, &params[2 * l]);
            add_bias(&mut h, &params[2 * l + 1]);
            if l != n_layers - 1 {
                h = relu(&h);
            }
            acts.push(h);
        }
        let logits = &acts[n_layers - 1];
        let (loss, mut delta) = cross_entropy_with_grad(logits, labels);

        // backward
        let mut grads: Vec<Tensor> = (0..self.n_params())
            .map(|_| Tensor::zeros(&[0]))
            .collect();
        for l in (0..n_layers).rev() {
            let a_in: &Tensor = if l == 0 { x2d } else { &acts[l - 1] };
            // dW = a_inᵀ @ delta ; db = colsum(delta)
            grads[2 * l] = matmul_at(a_in, &delta);
            let (rows, cols) = delta.as_2d();
            let mut db = Tensor::zeros(&[cols]);
            for r in 0..rows {
                for c in 0..cols {
                    db.data_mut()[c] += delta.data()[r * cols + c];
                }
            }
            grads[2 * l + 1] = db;
            if l > 0 {
                // dA = delta @ Wᵀ, gated by the ReLU mask of a_in
                let mut da = matmul_bt(&delta, &params[2 * l]);
                for (d, &a) in da.data_mut().iter_mut().zip(a_in.data()) {
                    if a <= 0.0 {
                        *d = 0.0;
                    }
                }
                delta = da;
            }
        }
        (loss, grads)
    }

    /// Mean cross-entropy loss + gradients over **packed** parameters — the
    /// frozen-mask fine-tuning backward pass.
    ///
    /// The forward runs the sparse kernels; the backward computes a
    /// [`PackedGrad::Compact`] for every packed weight via
    /// [`packed_matmul_at`](crate::sparsity::packed_matmul_at) (only kept
    /// coordinates are ever materialized — the gradient of a pruned slot
    /// does not exist) and streams the compressed weights through
    /// [`packed_matmul_bt`](crate::sparsity::packed_matmul_bt) for the
    /// activation gradient. Dense parameters (biases, final layer) get
    /// ordinary dense gradients.
    ///
    /// **Bit-for-bit** equal to [`Mlp::loss_and_grad`] over the dense
    /// *masked* parameter list: the loss, every dense gradient, and every
    /// kept coordinate of every compact gradient carry identical bits
    /// (`rust/tests/packed_finetune.rs` holds this across ratios, tails,
    /// and batch sizes).
    ///
    /// Decodes each packed weight's index codes per call; a training loop
    /// should decode once and use
    /// [`loss_and_grad_packed_with_cols`](Self::loss_and_grad_packed_with_cols)
    /// — [`FinetuneSession`](crate::coordinator::finetune::FinetuneSession)
    /// does.
    pub fn loss_and_grad_packed(
        &self,
        params: &[PackedParam],
        x: &Tensor,
        labels: &[usize],
    ) -> (f64, Vec<PackedGrad>) {
        let cols: Vec<Option<Vec<u32>>> = params
            .iter()
            .map(|p| p.as_packed().map(|pk| pk.col_indices()))
            .collect();
        self.loss_and_grad_packed_with_cols(params, &cols, x, labels)
    }

    /// [`loss_and_grad_packed`](Self::loss_and_grad_packed) with
    /// caller-cached column indices: `cols[i]` must be
    /// [`col_indices`](crate::sparsity::PackedNmTensor::col_indices) of
    /// packed parameter `i` (`None` for dense parameters). The codes are
    /// immutable during frozen-mask fine-tuning, so the cache is computed
    /// once per session and the hot loop never re-decodes the bitstream.
    pub fn loss_and_grad_packed_with_cols(
        &self,
        params: &[PackedParam],
        cols: &[Option<Vec<u32>>],
        x: &Tensor,
        labels: &[usize],
    ) -> (f64, Vec<PackedGrad>) {
        assert_eq!(params.len(), self.n_params(), "packed param arity");
        assert_eq!(params.len(), cols.len(), "cols cache arity");
        let n_layers = self.n_layers();
        let reshaped;
        let x2d: &Tensor = if x.ndim() == 2 {
            x // layer 0 only reads its input — no defensive copy
        } else {
            reshaped = x.clone().reshape(&[x.rows_2d(), x.last_dim()]);
            &reshaped
        };
        // one kernel scratch for the whole forward + backward pass
        let mut scratch = PackedScratch::new();
        let batch = x2d.rows_2d();
        // forward, caching each layer's post-ReLU output
        let mut acts: Vec<Tensor> = Vec::with_capacity(n_layers);
        for l in 0..n_layers {
            let input = if l == 0 { x2d } else { &acts[l - 1] };
            let b = params[2 * l + 1]
                .as_dense()
                // nm-lint: allow(panic-freedom): validate_packed_params at session construction guarantees dense biases
                .expect("bias tensors are never packed");
            let mut h = match &params[2 * l] {
                PackedParam::Dense(w) => matmul(input, w),
                PackedParam::Packed(w) => {
                    let mut c = Tensor::zeros(&[batch, self.sizes[l + 1]]);
                    packed_matmul_rows_into(input.data(), batch, w, &mut c, &mut scratch);
                    c
                }
            };
            add_bias(&mut h, b);
            if l != n_layers - 1 {
                h = relu(&h);
            }
            acts.push(h);
        }
        let logits = &acts[n_layers - 1];
        let (loss, mut delta) = cross_entropy_with_grad(logits, labels);

        // backward
        let mut grads: Vec<PackedGrad> = (0..self.n_params())
            .map(|_| PackedGrad::Dense(Tensor::zeros(&[0])))
            .collect();
        for l in (0..n_layers).rev() {
            let a_in: &Tensor = if l == 0 { x2d } else { &acts[l - 1] };
            grads[2 * l] = match &params[2 * l] {
                PackedParam::Dense(_) => PackedGrad::Dense(matmul_at(a_in, &delta)),
                PackedParam::Packed(w) => {
                    // nm-lint: allow(panic-freedom): cols_cache builds an entry for every packed param
                    let ci = cols[2 * l].as_ref().expect("packed param lacks cols cache");
                    let mut gv = vec![0f32; w.n_values()];
                    packed_matmul_at_into(a_in, &delta, w, ci, &mut gv);
                    PackedGrad::Compact(gv)
                }
            };
            // db = colsum(delta), identical to the dense path
            let (rows, dcols) = delta.as_2d();
            let mut db = Tensor::zeros(&[dcols]);
            for r in 0..rows {
                for c in 0..dcols {
                    db.data_mut()[c] += delta.data()[r * dcols + c];
                }
            }
            grads[2 * l + 1] = PackedGrad::Dense(db);
            if l > 0 {
                // dA = delta @ Wᵀ (compressed-weight stream), ReLU-gated
                let mut da = match &params[2 * l] {
                    PackedParam::Dense(w) => matmul_bt(&delta, w),
                    PackedParam::Packed(w) => {
                        // nm-lint: allow(panic-freedom): cols_cache builds an entry for every packed param
                        let ci = cols[2 * l].as_ref().expect("packed param lacks cols cache");
                        let mut out = Tensor::zeros(&[rows, w.shape()[0]]);
                        packed_matmul_bt_tiled_into(&delta, w, ci, &mut out, &mut scratch);
                        out
                    }
                };
                for (d, &a) in da.data_mut().iter_mut().zip(a_in.data()) {
                    if a <= 0.0 {
                        *d = 0.0;
                    }
                }
                delta = da;
            }
        }
        (loss, grads)
    }

    /// Classification accuracy on a batch.
    pub fn accuracy(&self, params: &[Tensor], x: &Tensor, labels: &[usize]) -> f64 {
        accuracy_from_logits(&self.forward(params, x), labels)
    }

    /// Reconstruct the pure-Rust [`Mlp`] a manifest model describes — only
    /// models with the `[w, b, …]` classifier layout qualify (the Table-1
    /// MLP analogs); anything else gets a clear error instead of silent
    /// garbage. Token models resolve through
    /// [`model_from_info`](super::model_from_info) instead.
    pub fn from_model_info(info: &ModelInfo) -> anyhow::Result<Self> {
        anyhow::ensure!(
            info.kind == "classify",
            "the MLP layout serves classifiers (model {:?} has kind {:?})",
            info.key,
            info.kind
        );
        anyhow::ensure!(
            !info.params.is_empty() && info.params.len() % 2 == 0,
            "model {:?}: expected alternating [w, b] params, got {}",
            info.key,
            info.params.len()
        );
        let mut sizes: Vec<usize> = Vec::with_capacity(info.params.len() / 2 + 1);
        for l in 0..info.params.len() / 2 {
            let (_, wshape, _) = &info.params[2 * l];
            let (_, bshape, _) = &info.params[2 * l + 1];
            anyhow::ensure!(
                wshape.len() == 2 && bshape.len() == 1 && bshape[0] == wshape[1],
                "model {:?} layer {l} is not an MLP [w, b] pair ({wshape:?}, {bshape:?})",
                info.key
            );
            if let Some(&prev) = sizes.last() {
                anyhow::ensure!(
                    wshape[0] == prev,
                    "model {:?} layer {l}: fan-in {} vs previous fan-out {prev}",
                    info.key,
                    wshape[0]
                );
            } else {
                sizes.push(wshape[0]);
            }
            sizes.push(wshape[1]);
        }
        anyhow::ensure!(
            sizes.last() == Some(&info.n_classes),
            "model {:?}: final fan-out {:?} != n_classes {}",
            info.key,
            sizes.last(),
            info.n_classes
        );
        Ok(Mlp { sizes })
    }

    /// Describe this MLP as a manifest-style [`ModelInfo`] (the inverse of
    /// [`from_model_info`](Self::from_model_info), used by the dispatch
    /// round-trip tests and checkpoint tooling).
    pub fn model_info(&self, key: &str, batch: usize) -> ModelInfo {
        let mut params = Vec::with_capacity(self.n_params());
        let flags = self.sparse_flags();
        for l in 0..self.n_layers() {
            params.push((
                format!("fc{l}_w"),
                vec![self.sizes[l], self.sizes[l + 1]],
                flags[2 * l],
            ));
            params.push((format!("fc{l}_b"), vec![self.sizes[l + 1]], false));
        }
        let sparse_indices = flags
            .iter()
            .enumerate()
            .filter_map(|(i, &s)| s.then_some(i))
            .collect();
        // scalar count straight from the layer sizes (Mlp::dim would run a
        // throwaway parameter init just to sum numels)
        let dim = (0..self.n_layers())
            .map(|l| self.sizes[l] * self.sizes[l + 1] + self.sizes[l + 1])
            .sum();
        ModelInfo {
            key: key.to_string(),
            params,
            sparse_indices,
            kind: "classify".to_string(),
            n_classes: self.sizes[self.sizes.len() - 1],
            dim,
            batch,
            seq: None,
        }
    }
}

impl super::SparseModel for Mlp {
    fn n_params(&self) -> usize {
        Mlp::n_params(self)
    }

    fn in_dim(&self) -> usize {
        self.sizes[0]
    }

    fn out_dim(&self) -> usize {
        self.sizes[self.sizes.len() - 1]
    }

    fn init(&self, rng: &mut Pcg64) -> Vec<Tensor> {
        Mlp::init(self, rng)
    }

    fn sparse_flags(&self) -> Vec<bool> {
        Mlp::sparse_flags(self)
    }

    fn forward(&self, params: &[Tensor], x: &Tensor) -> Tensor {
        Mlp::forward(self, params, x)
    }

    fn loss_and_grad(&self, params: &[Tensor], x: &Tensor, labels: &[usize]) -> (f64, Vec<Tensor>) {
        Mlp::loss_and_grad(self, params, x, labels)
    }

    fn forward_packed(&self, params: &[PackedParam], x: &Tensor) -> Tensor {
        Mlp::forward_packed(self, params, x)
    }

    fn loss_and_grad_packed_with_cols(
        &self,
        params: &[PackedParam],
        cols: &[Option<Vec<u32>>],
        x: &Tensor,
        labels: &[usize],
    ) -> (f64, Vec<PackedGrad>) {
        Mlp::loss_and_grad_packed_with_cols(self, params, cols, x, labels)
    }

    fn validate_packed_params(&self, params: &[PackedParam]) -> anyhow::Result<()> {
        Mlp::validate_packed_params(self, params)
    }

    // the copy-free sharded entry (the provided default would materialize a
    // tensor around every serving shard)
    fn forward_packed_rows(
        &self,
        params: &[PackedParam],
        xs: &[f32],
        rows: usize,
        dim: usize,
    ) -> Tensor {
        assert_eq!(dim, self.sizes[0], "MLP shard row width {dim} vs {}", self.sizes[0]);
        Mlp::forward_packed_rows(self, params, xs, rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::Cases;

    #[test]
    fn shapes_and_flags() {
        let mlp = Mlp::new(8, &[16, 12], 3);
        assert_eq!(mlp.n_layers(), 3);
        assert_eq!(mlp.n_params(), 6);
        assert_eq!(
            mlp.sparse_flags(),
            vec![true, false, true, false, false, false]
        );
        let p = mlp.init(&mut Pcg64::new(0));
        assert_eq!(p[0].shape(), &[8, 16]);
        assert_eq!(p[5].shape(), &[3]);
    }

    #[test]
    fn forward_shapes() {
        let mlp = Mlp::new(8, &[16], 3);
        let p = mlp.init(&mut Pcg64::new(1));
        let x = Tensor::randn(&[5, 8], &mut Pcg64::new(2), 0.0, 1.0);
        let y = mlp.forward(&p, &x);
        assert_eq!(y.shape(), &[5, 3]);
    }

    #[test]
    fn gradients_match_finite_differences() {
        Cases::new(4).run(|rng, _| {
            let mlp = Mlp::new(4, &[6], 3);
            let params = mlp.init(rng);
            let x = Tensor::randn(&[3, 4], rng, 0.0, 1.0);
            let labels = vec![rng.below(3), rng.below(3), rng.below(3)];
            let (loss, grads) = mlp.loss_and_grad(&params, &x, &labels);
            let eps = 1e-3f32;
            // probe a handful of random coordinates of each tensor
            for (pi, g) in grads.iter().enumerate() {
                for _probe in 0..4 {
                    let idx = rng.below(g.numel());
                    let mut pp = params.clone();
                    pp[pi].data_mut()[idx] += eps;
                    let (l2, _) = mlp.loss_and_grad(&pp, &x, &labels);
                    let fd = (l2 - loss) / eps as f64;
                    let an = g.data()[idx] as f64;
                    assert!(
                        (fd - an).abs() < 5e-2 * (1.0 + an.abs()),
                        "param {pi} idx {idx}: fd {fd} vs {an}"
                    );
                }
            }
        });
    }

    #[test]
    fn packed_forward_matches_dense_masked() {
        let mlp = Mlp::new(16, &[24, 16], 5);
        let mut rng = Pcg64::new(4);
        let params = mlp.init(&mut rng);
        let ratio = NmRatio::new(2, 4);
        let masked = mlp.masked_params(&params, ratio);
        let packed = mlp.pack_params(&params, ratio);
        for batch in [1usize, 5, 8, 11] {
            let x = Tensor::randn(&[batch, 16], &mut rng, 0.0, 1.0);
            let dense = mlp.forward(&masked, &x);
            let sparse = mlp.forward_packed(&packed, &x);
            assert_eq!(dense, sparse, "batch {batch}");
            let labels: Vec<usize> = (0..batch).map(|i| i % 5).collect();
            assert_eq!(
                mlp.accuracy(&masked, &x, &labels),
                mlp.accuracy_packed(&packed, &x, &labels)
            );
        }
    }

    #[test]
    fn forward_packed_rows_matches_forward_packed() {
        let mlp = Mlp::new(12, &[16, 8], 4);
        let mut rng = Pcg64::new(6);
        let params = mlp.init(&mut rng);
        let packed = mlp.pack_params(&params, NmRatio::new(2, 4));
        let x = Tensor::randn(&[9, 12], &mut rng, 0.0, 1.0);
        let whole = mlp.forward_packed(&packed, &x);
        // a row sub-range through the slice entry, like a serving shard
        let shard = mlp.forward_packed_rows(&packed, &x.data()[2 * 12..7 * 12], 5);
        assert_eq!(shard.data(), &whole.data()[2 * 4..7 * 4]);
    }

    #[test]
    fn packed_loss_and_grad_matches_dense_masked_oracle() {
        let mlp = Mlp::new(8, &[16, 12], 3);
        let mut rng = Pcg64::new(11);
        let params = mlp.init(&mut rng);
        let ratio = NmRatio::new(2, 4);
        let masked = mlp.masked_params(&params, ratio);
        let packed = mlp.pack_params(&params, ratio);
        let x = Tensor::randn(&[10, 8], &mut rng, 0.0, 1.0);
        let labels: Vec<usize> = (0..10).map(|i| i % 3).collect();
        let (loss_d, grads_d) = mlp.loss_and_grad(&masked, &x, &labels);
        let (loss_p, grads_p) = mlp.loss_and_grad_packed(&packed, &x, &labels);
        assert_eq!(loss_d.to_bits(), loss_p.to_bits());
        for (i, (gd, gp)) in grads_d.iter().zip(&grads_p).enumerate() {
            match (&packed[i], gp) {
                (PackedParam::Packed(pk), PackedGrad::Compact(cv)) => {
                    // compact grad == dense grad gathered at kept slots
                    assert_eq!(pk.compact_like(gd), *cv, "param {i}");
                }
                (PackedParam::Dense(_), PackedGrad::Dense(gt)) => {
                    assert_eq!(gd, gt, "param {i}");
                }
                other => panic!("param {i}: mismatched grad kind {other:?}"),
            }
        }
    }

    #[test]
    fn training_reduces_loss() {
        let mut rng = Pcg64::new(3);
        let mlp = Mlp::new(10, &[32], 4);
        let mut params = mlp.init(&mut rng);
        // fixed synthetic batch: learn to classify by cluster
        let x = Tensor::randn(&[64, 10], &mut rng, 0.0, 1.0);
        let labels: Vec<usize> = (0..64).map(|i| i % 4).collect();
        let (first, _) = mlp.loss_and_grad(&params, &x, &labels);
        for _ in 0..200 {
            let (_, grads) = mlp.loss_and_grad(&params, &x, &labels);
            for (p, g) in params.iter_mut().zip(&grads) {
                crate::tensor::axpy(p, -0.5, g);
            }
        }
        let (last, _) = mlp.loss_and_grad(&params, &x, &labels);
        assert!(last < first * 0.5, "{first} -> {last}");
        assert!(mlp.accuracy(&params, &x, &labels) > 0.8);
    }

    #[test]
    fn model_info_round_trips() {
        let mlp = Mlp::new(8, &[16, 12], 3);
        let info = mlp.model_info("mlp_rt", 4);
        let back = Mlp::from_model_info(&info).unwrap();
        assert_eq!(back.sizes, mlp.sizes);
        assert_eq!(info.sparse_indices, vec![0, 2]);
        assert_eq!(info.dim, mlp.dim());
    }
}
