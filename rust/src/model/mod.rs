//! The pure-Rust model zoo behind one interface: [`SparseModel`].
//!
//! Every downstream layer of the STEP pipeline — the recipe engine
//! ([`crate::optim::RecipeState`]), the packed frozen-mask fine-tuner
//! ([`crate::coordinator::finetune::FinetuneSession`]), the streaming
//! driver ([`crate::coordinator::driver::TrainDriver`]), and the batch
//! server ([`crate::coordinator::serve::BatchServer`]) — is generic over
//! this trait, so the same train → STEP switch → pack → packed fine-tune →
//! serve pipeline runs any model that can state its parameter layout and
//! compute exact dense + packed gradients:
//!
//! * [`Mlp`] — the ReLU classifier of the Table-1 vision analogs (hidden
//!   weights sparse-eligible, head dense).
//! * [`TokenEncoder`] — a pure-Rust attention encoder (fused-QKV attention
//!   with exact softmax backprop, FFN, residuals; all projection matrices
//!   sparse-eligible, embeddings/biases/head dense) — the paper's central
//!   BERT/GPT-2 workload family.
//! * [`TokenDecoder`] — the causal pre-norm decoder (separate-QKV
//!   projections, LayerNorm with an exact analytic backward from
//!   [`norm`], last-token next-token head) — the legacy manifest layout,
//!   plus KV-cached incremental decoding
//!   ([`TokenDecoder::decode_step_packed`]) for token-by-token batched
//!   generation over packed weights.
//! * [`AnyModel`] — the runtime dispatch over all three, resolved from a
//!   manifest [`ModelInfo`] by [`model_from_info`].
//!
//! The **bit-identity contract** is part of the trait: for finite inputs,
//! `forward_packed` over packed parameters must equal `forward` over the
//! dense *masked* parameter list bit-for-bit, and
//! `loss_and_grad_packed_with_cols` must reproduce the dense masked
//! `loss_and_grad` on every kept coordinate. Both implementations satisfy
//! it by running the identical code path with only the matmul kernels
//! swapped (the kernel-level equalities live in
//! [`crate::sparsity::packed`]).

pub mod decoder;
pub mod encoder;
pub mod mlp;
pub mod norm;
mod weights;

pub use decoder::{DecoderKvCache, TokenDecoder};
pub use encoder::{Pool, TokenEncoder};
pub use mlp::Mlp;

use crate::rng::Pcg64;
use crate::runtime::ModelInfo;
use crate::sparsity::{NmRatio, PackedGrad, PackedParam};
use crate::tensor::{accuracy_from_logits, Tensor};

/// A model the whole STEP pipeline can drive: dense training, N:M mask
/// learning, packed inference, and packed frozen-mask fine-tuning.
///
/// Parameters are a flat ordered `Vec<Tensor>`; [`sparse_flags`]
/// (per-tensor N:M eligibility) is the single source the mask, pack, and
/// export layers derive their ratio vectors from.
///
/// [`sparse_flags`]: SparseModel::sparse_flags
///
/// # Examples
///
/// Downstream code stays model-agnostic — this generic step runs unchanged
/// over the MLP and the token encoder:
///
/// ```
/// use step_nm::model::{Mlp, SparseModel, TokenEncoder};
/// use step_nm::rng::Pcg64;
/// use step_nm::sparsity::NmRatio;
/// use step_nm::tensor::Tensor;
///
/// fn masked_loss<M: SparseModel>(model: &M, x: &Tensor, labels: &[usize]) -> f64 {
///     let params = model.init(&mut Pcg64::new(0));
///     let masked = model.masked_params(&params, NmRatio::new(2, 4));
///     model.loss_and_grad(&masked, x, labels).0
/// }
///
/// let mlp = Mlp::new(8, &[16], 3);
/// let x = Tensor::randn(&[2, 8], &mut Pcg64::new(1), 0.0, 1.0);
/// assert!(masked_loss(&mlp, &x, &[0, 2]) > 0.0);
///
/// let enc = TokenEncoder::classifier(10, 8, 2, 16, 1, 4, 3);
/// let ids = Tensor::new(&[2, 4], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
/// assert!(masked_loss(&enc, &ids, &[0, 2]) > 0.0);
/// ```
pub trait SparseModel: Clone + Send + Sync {
    /// Number of parameter tensors.
    fn n_params(&self) -> usize;

    /// Nominal trailing input dimension (feature width for MLPs, `max_seq`
    /// for token models) — see [`check_input_dim`](Self::check_input_dim)
    /// for the serve-time validation rule.
    fn in_dim(&self) -> usize;

    /// Logit width (`n_classes`, or the vocabulary for next-token heads).
    fn out_dim(&self) -> usize;

    /// Seeded parameter init, in layout order.
    fn init(&self, rng: &mut Pcg64) -> Vec<Tensor>;

    /// Per-tensor N:M eligibility (the model zoo convention: projection /
    /// hidden weights yes; embeddings, biases, heads no).
    fn sparse_flags(&self) -> Vec<bool>;

    /// Forward pass: logits `[batch, out_dim]`.
    fn forward(&self, params: &[Tensor], x: &Tensor) -> Tensor;

    /// Mean cross-entropy loss + exact gradients w.r.t. every parameter.
    fn loss_and_grad(&self, params: &[Tensor], x: &Tensor, labels: &[usize])
        -> (f64, Vec<Tensor>);

    /// Forward over **packed** parameters — bit-identical to [`forward`]
    /// over the dense masked list on finite inputs.
    ///
    /// [`forward`]: SparseModel::forward
    fn forward_packed(&self, params: &[PackedParam], x: &Tensor) -> Tensor;

    /// Packed loss + gradients with a caller-cached column-index decode
    /// (`cols[i]` = `col_indices()` of packed parameter `i`, `None` for
    /// dense) — compact gradients for packed weights, dense otherwise.
    fn loss_and_grad_packed_with_cols(
        &self,
        params: &[PackedParam],
        cols: &[Option<Vec<u32>>],
        x: &Tensor,
        labels: &[usize],
    ) -> (f64, Vec<PackedGrad>);

    /// Validate a packed parameter list against this model's layout.
    fn validate_packed_params(&self, params: &[PackedParam]) -> anyhow::Result<()>;

    // ---- provided ---------------------------------------------------------

    /// Serve-time input validation: accept a batch whose trailing dimension
    /// is `dim`? Default: must equal [`in_dim`](Self::in_dim) exactly
    /// (token models override to accept shorter sequences).
    fn check_input_dim(&self, dim: usize) -> anyhow::Result<()> {
        anyhow::ensure!(
            dim == self.in_dim(),
            "batch feature dim {dim} does not match model input dim {}",
            self.in_dim()
        );
        Ok(())
    }

    /// Full serve-time batch validation: reject any input the model would
    /// panic on, as an error. The default checks the trailing dimension;
    /// token models additionally validate every id, so
    /// [`BatchServer`](crate::coordinator::serve::BatchServer) can hold its
    /// "failed calls error out and are never counted" contract for every
    /// model family.
    fn validate_input(&self, x: &Tensor) -> anyhow::Result<()> {
        self.check_input_dim(x.last_dim())
    }

    /// Total scalar parameter count.
    fn dim(&self) -> usize {
        self.init(&mut Pcg64::new(0)).iter().map(|t| t.numel()).sum()
    }

    /// Uniform ratio vector from the flags (`None` = dense tensor).
    fn ratios(&self, ratio: NmRatio) -> Vec<Option<NmRatio>> {
        self.sparse_flags()
            .into_iter()
            .map(|s| if s { Some(ratio) } else { None })
            .collect()
    }

    /// The dense **masked** parameter list: `Π ⊙ w` on sparse-eligible
    /// tensors, clones elsewhere — the oracle every packed path is held
    /// bit-identical to.
    fn masked_params(&self, params: &[Tensor], ratio: NmRatio) -> Vec<Tensor> {
        params
            .iter()
            .zip(self.sparse_flags())
            .map(|(p, s)| if s { crate::sparsity::apply_nm(p, ratio) } else { p.clone() })
            .collect()
    }

    /// Pack trained parameters for inference at `ratio` (sparse-eligible
    /// tensors compressed, everything else dense).
    fn pack_params(&self, params: &[Tensor], ratio: NmRatio) -> Vec<PackedParam> {
        crate::sparsity::pack_params(params, &self.ratios(ratio))
    }

    /// [`loss_and_grad_packed_with_cols`] with a per-call decode (training
    /// loops should cache the decode instead — `FinetuneSession` does).
    ///
    /// [`loss_and_grad_packed_with_cols`]: SparseModel::loss_and_grad_packed_with_cols
    fn loss_and_grad_packed(
        &self,
        params: &[PackedParam],
        x: &Tensor,
        labels: &[usize],
    ) -> (f64, Vec<PackedGrad>) {
        let cols: Vec<Option<Vec<u32>>> = params
            .iter()
            .map(|p| p.as_packed().map(|pk| pk.col_indices()))
            .collect();
        self.loss_and_grad_packed_with_cols(params, &cols, x, labels)
    }

    /// Packed forward over a **borrowed** row-major slice of `rows` samples
    /// of `dim` trailing features each — the threaded
    /// [`BatchServer`](crate::coordinator::serve::BatchServer) shard entry.
    /// The default materializes one tensor around the shard; models with a
    /// copy-free path (the MLP) override it.
    fn forward_packed_rows(
        &self,
        params: &[PackedParam],
        xs: &[f32],
        rows: usize,
        dim: usize,
    ) -> Tensor {
        assert_eq!(xs.len(), rows * dim, "shard slice {} vs {rows}x{dim}", xs.len());
        let x = Tensor::new(&[rows, dim], xs.to_vec());
        self.forward_packed(params, &x)
    }

    /// Classification accuracy on a batch.
    fn accuracy(&self, params: &[Tensor], x: &Tensor, labels: &[usize]) -> f64 {
        accuracy_from_logits(&self.forward(params, x), labels)
    }

    /// Classification accuracy of a packed model on a batch.
    fn accuracy_packed(&self, params: &[PackedParam], x: &Tensor, labels: &[usize]) -> f64 {
        accuracy_from_logits(&self.forward_packed(params, x), labels)
    }
}

/// Runtime model dispatch: the concrete model a manifest [`ModelInfo`]
/// resolves to (see [`model_from_info`]). Implements [`SparseModel`] by
/// delegation, so `Session::batch_server` / `finetune_session` serve both
/// families through one type.
#[derive(Debug, Clone)]
pub enum AnyModel {
    Mlp(Mlp),
    Encoder(TokenEncoder),
    Decoder(TokenDecoder),
}

macro_rules! any_delegate {
    ($self:ident, $m:ident, $body:expr) => {
        match $self {
            AnyModel::Mlp($m) => $body,
            AnyModel::Encoder($m) => $body,
            AnyModel::Decoder($m) => $body,
        }
    };
}

impl SparseModel for AnyModel {
    fn n_params(&self) -> usize {
        any_delegate!(self, m, SparseModel::n_params(m))
    }

    fn in_dim(&self) -> usize {
        any_delegate!(self, m, SparseModel::in_dim(m))
    }

    fn out_dim(&self) -> usize {
        any_delegate!(self, m, SparseModel::out_dim(m))
    }

    fn init(&self, rng: &mut Pcg64) -> Vec<Tensor> {
        any_delegate!(self, m, SparseModel::init(m, rng))
    }

    fn sparse_flags(&self) -> Vec<bool> {
        any_delegate!(self, m, SparseModel::sparse_flags(m))
    }

    fn forward(&self, params: &[Tensor], x: &Tensor) -> Tensor {
        any_delegate!(self, m, SparseModel::forward(m, params, x))
    }

    fn loss_and_grad(
        &self,
        params: &[Tensor],
        x: &Tensor,
        labels: &[usize],
    ) -> (f64, Vec<Tensor>) {
        any_delegate!(self, m, SparseModel::loss_and_grad(m, params, x, labels))
    }

    fn forward_packed(&self, params: &[PackedParam], x: &Tensor) -> Tensor {
        any_delegate!(self, m, SparseModel::forward_packed(m, params, x))
    }

    fn loss_and_grad_packed_with_cols(
        &self,
        params: &[PackedParam],
        cols: &[Option<Vec<u32>>],
        x: &Tensor,
        labels: &[usize],
    ) -> (f64, Vec<PackedGrad>) {
        any_delegate!(
            self,
            m,
            SparseModel::loss_and_grad_packed_with_cols(m, params, cols, x, labels)
        )
    }

    fn validate_packed_params(&self, params: &[PackedParam]) -> anyhow::Result<()> {
        any_delegate!(self, m, SparseModel::validate_packed_params(m, params))
    }

    fn check_input_dim(&self, dim: usize) -> anyhow::Result<()> {
        any_delegate!(self, m, SparseModel::check_input_dim(m, dim))
    }

    fn validate_input(&self, x: &Tensor) -> anyhow::Result<()> {
        any_delegate!(self, m, SparseModel::validate_input(m, x))
    }

    fn forward_packed_rows(
        &self,
        params: &[PackedParam],
        xs: &[f32],
        rows: usize,
        dim: usize,
    ) -> Tensor {
        any_delegate!(self, m, SparseModel::forward_packed_rows(m, params, xs, rows, dim))
    }
}

/// Resolve a manifest model description to a concrete pure-Rust model —
/// the dispatcher behind `Session::batch_server` / `finetune_session`.
///
/// Classifier layouts with alternating `[w, b]` pairs resolve to [`Mlp`];
/// fused-QKV token-model layouts (`tok_emb` / `pos_emb_h<heads>` followed
/// by QKV blocks and a dense head, kind `"classify"` or `"lm"`) resolve to
/// [`TokenEncoder`]; separate-QKV + LayerNorm layouts — including the
/// legacy manifests with a plain untagged `pos_emb` — resolve to
/// [`TokenDecoder`]. Anything else gets an error naming every attempt
/// instead of silent garbage.
pub fn model_from_info(info: &ModelInfo) -> anyhow::Result<AnyModel> {
    let mlp_err = if info.kind == "classify" {
        match Mlp::from_model_info(info) {
            Ok(mlp) => return Ok(AnyModel::Mlp(mlp)),
            Err(e) => Some(e),
        }
    } else {
        None
    };
    let enc_err = match TokenEncoder::from_model_info(info) {
        Ok(enc) => return Ok(AnyModel::Encoder(enc)),
        Err(e) => e,
    };
    let dec_err = match TokenDecoder::from_model_info(info) {
        Ok(dec) => return Ok(AnyModel::Decoder(dec)),
        Err(e) => e,
    };
    Err(match mlp_err {
        Some(mlp_err) => anyhow::anyhow!(
            "model {:?} matches no pure-Rust layout (MLP: {mlp_err}; encoder: {enc_err}; \
             decoder: {dec_err})",
            info.key
        ),
        None => anyhow::anyhow!(
            "model {:?} matches no pure-Rust layout (encoder: {enc_err}; decoder: {dec_err})",
            info.key
        ),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_from_info_dispatches_mlp_layouts() {
        let info = ModelInfo {
            key: "mlp_test".into(),
            params: vec![
                ("w0".into(), vec![8, 16], true),
                ("b0".into(), vec![16], false),
                ("w1".into(), vec![16, 4], false),
                ("b1".into(), vec![4], false),
            ],
            sparse_indices: vec![0],
            kind: "classify".into(),
            n_classes: 4,
            dim: 8 * 16 + 16 + 16 * 4 + 4,
            batch: 2,
            seq: None,
        };
        let AnyModel::Mlp(mlp) = model_from_info(&info).unwrap() else {
            panic!("MLP layout must dispatch to Mlp");
        };
        assert_eq!(mlp.sizes, vec![8, 16, 4]);
    }

    /// LM-family layouts dispatch to the encoder — this used to be the
    /// `mlp_from_model_info(&lm).is_err()` rejection test.
    #[test]
    fn model_from_info_dispatches_lm_layouts_to_the_encoder() {
        let enc = TokenEncoder::next_token(32, 8, 2, 16, 2, 6);
        let info = enc.model_info("lm_test", 4);
        assert_eq!(info.kind, "lm");
        let AnyModel::Encoder(back) = model_from_info(&info).unwrap() else {
            panic!("LM layout must dispatch to TokenEncoder");
        };
        assert_eq!(back.vocab, enc.vocab);
        assert_eq!(back.pool, Pool::Last);
        assert_eq!(back.n_heads, enc.n_heads);

        // token classifiers (GLUE analogs) dispatch to the encoder too
        let cls = TokenEncoder::classifier(16, 8, 4, 12, 1, 5, 3);
        let cinfo = cls.model_info("enc_test", 4);
        assert_eq!(cinfo.kind, "classify");
        let AnyModel::Encoder(cback) = model_from_info(&cinfo).unwrap() else {
            panic!("token classifier layout must dispatch to TokenEncoder");
        };
        assert_eq!(cback.pool, Pool::First);
        assert_eq!(cback.n_out, 3);
    }

    /// The legacy separate-QKV + LayerNorm manifest layout — the exact
    /// plain-`pos_emb` naming the old manifests used — dispatches to
    /// [`TokenDecoder`] and round-trips. This used to be an `is_err`
    /// rejection test, open since PR 5.
    #[test]
    fn model_from_info_dispatches_legacy_layernorm_layouts_to_the_decoder() {
        let lm = ModelInfo {
            key: "lm_legacy".into(),
            params: vec![
                ("tok_emb".into(), vec![32, 8], false),
                ("pos_emb".into(), vec![6, 8], false), // no head-count tag: 1 head
                ("l0_ln1_g".into(), vec![8], false),
                ("l0_ln1_b".into(), vec![8], false),
                ("l0_wq".into(), vec![8, 8], true),
                ("l0_wk".into(), vec![8, 8], true),
                ("l0_wv".into(), vec![8, 8], true),
                ("l0_wo".into(), vec![8, 8], true),
                ("l0_ln2_g".into(), vec![8], false),
                ("l0_ln2_b".into(), vec![8], false),
                ("l0_fc1_w".into(), vec![8, 32], true),
                ("l0_fc1_b".into(), vec![32], false),
                ("l0_fc2_w".into(), vec![32, 8], true),
                ("l0_fc2_b".into(), vec![8], false),
                ("lnf_g".into(), vec![8], false),
                ("lnf_b".into(), vec![8], false),
                ("head_w".into(), vec![8, 32], false),
                ("head_b".into(), vec![32], false),
            ],
            sparse_indices: vec![4, 5, 6, 7, 10, 12],
            kind: "lm".into(),
            n_classes: 32,
            dim: 0,
            batch: 1,
            seq: Some(6),
        };
        let AnyModel::Decoder(dec) = model_from_info(&lm).unwrap() else {
            panic!("legacy LayerNorm layout must dispatch to TokenDecoder");
        };
        assert_eq!(dec.vocab, 32);
        assert_eq!(dec.d_model, 8);
        assert_eq!(dec.n_heads, 1, "plain pos_emb reads as single-head");
        assert_eq!(dec.d_ff, 32);
        assert_eq!(dec.n_blocks, 1);
        assert_eq!(dec.max_seq, 6);
        // and the decoder's own manifest reproduces the legacy naming
        let info = dec.model_info("lm_legacy", 1);
        assert_eq!(info.params[1].0, "pos_emb");
        assert_eq!(info.sparse_indices, vec![4, 5, 6, 7, 10, 12]);
    }

    #[test]
    fn model_from_info_rejects_foreign_layouts_with_every_attempt() {
        // a classify layout that matches no family names all three attempts
        let info = ModelInfo {
            key: "weird".into(),
            params: vec![("w".into(), vec![4, 4, 4], true)],
            sparse_indices: vec![0],
            kind: "classify".into(),
            n_classes: 4,
            dim: 64,
            batch: 1,
            seq: None,
        };
        let err = model_from_info(&info).unwrap_err().to_string();
        assert!(err.contains("matches no pure-Rust layout"), "unhelpful error: {err}");
        assert!(err.contains("MLP:") && err.contains("decoder:"), "missing attempts: {err}");
        // a truncated legacy LM layout (separate QKV but no LayerNorm
        // tensors) fits neither token family: error, not silent garbage
        let lm = ModelInfo {
            key: "lm_no_norms".into(),
            params: vec![
                ("tok_emb".into(), vec![32, 8], false),
                ("pos_emb".into(), vec![6, 8], false),
                ("l0_wq".into(), vec![8, 8], true),
                ("l0_wk".into(), vec![8, 8], true),
                ("l0_wv".into(), vec![8, 8], true),
                ("l0_wo".into(), vec![8, 8], true),
                ("l0_fc1_w".into(), vec![8, 32], true),
                ("l0_fc1_b".into(), vec![32], false),
                ("l0_fc2_w".into(), vec![32, 8], true),
                ("l0_fc2_b".into(), vec![8], false),
                ("head_w".into(), vec![8, 32], false),
                ("head_b".into(), vec![32], false),
            ],
            sparse_indices: vec![2, 3, 4, 5, 6, 8],
            kind: "lm".into(),
            n_classes: 32,
            dim: 0,
            batch: 1,
            seq: Some(6),
        };
        let err = model_from_info(&lm).unwrap_err().to_string();
        assert!(err.contains("matches no pure-Rust layout"), "unhelpful error: {err}");
        assert!(err.contains("encoder:") && err.contains("decoder:"), "missing attempts: {err}");
    }

    #[test]
    fn any_model_delegates_the_pipeline_surface() {
        let any = AnyModel::Mlp(Mlp::new(8, &[16], 3));
        assert_eq!(any.n_params(), 4);
        assert_eq!(any.in_dim(), 8);
        assert_eq!(any.out_dim(), 3);
        let params = any.init(&mut Pcg64::new(0));
        let packed = any.pack_params(&params, NmRatio::new(2, 4));
        any.validate_packed_params(&packed).unwrap();
        let x = Tensor::randn(&[3, 8], &mut Pcg64::new(1), 0.0, 1.0);
        let masked = any.masked_params(&params, NmRatio::new(2, 4));
        assert_eq!(any.forward(&masked, &x), any.forward_packed(&packed, &x));
    }
}
