//! Pure-Rust MLP with exact backprop — the fast-CPU substrate for the
//! many-seed / many-step experiments (Table 1 traces, Theorem-1 checks,
//! Fig. 7's switch-ratio sweep) where per-step PJRT dispatch would dominate.
//!
//! The layout mirrors `python/compile/models.mlp`: parameters are the flat
//! ordered list `[fc0_w, fc0_b, fc1_w, fc1_b, …]` with hidden weight
//! matrices sparse-eligible and the final layer dense, so recipe code (and
//! the manifest conventions) transfer unchanged between the two engines.

use crate::rng::Pcg64;
use crate::sparsity::NmRatio;
use crate::tensor::{
    add_bias, argmax_rows, cross_entropy_with_grad, matmul, matmul_at, matmul_bt, relu, Tensor,
};

/// An MLP classifier: `in_dim → hidden… → n_classes`, ReLU activations.
#[derive(Debug, Clone)]
pub struct Mlp {
    pub sizes: Vec<usize>,
}

impl Mlp {
    pub fn new(in_dim: usize, hidden: &[usize], n_classes: usize) -> Self {
        let mut sizes = vec![in_dim];
        sizes.extend_from_slice(hidden);
        sizes.push(n_classes);
        Self { sizes }
    }

    pub fn n_layers(&self) -> usize {
        self.sizes.len() - 1
    }

    /// Number of parameter tensors (2 per layer: weight, bias).
    pub fn n_params(&self) -> usize {
        2 * self.n_layers()
    }

    /// Total scalar parameter count.
    pub fn dim(&self) -> usize {
        self.init(&mut Pcg64::new(0)).iter().map(|t| t.numel()).sum()
    }

    /// Fan-in-scaled init matching `models._init_param` (weights ~
    /// N(0, 1/fan_in), biases zero).
    pub fn init(&self, rng: &mut Pcg64) -> Vec<Tensor> {
        let mut out = Vec::with_capacity(self.n_params());
        for l in 0..self.n_layers() {
            let (fan_in, fan_out) = (self.sizes[l], self.sizes[l + 1]);
            let scale = 1.0 / (fan_in as f32).sqrt();
            out.push(Tensor::randn(&[fan_in, fan_out], rng, 0.0, scale));
            out.push(Tensor::zeros(&[fan_out]));
        }
        out
    }

    /// Sparse-eligibility per parameter tensor: hidden weights yes, last
    /// layer and biases no — matching the Python model zoo.
    pub fn sparse_flags(&self) -> Vec<bool> {
        let n = self.n_layers();
        (0..self.n_params())
            .map(|i| i % 2 == 0 && i / 2 != n - 1)
            .collect()
    }

    /// Uniform ratio vector from the flags (`None` = dense tensor).
    pub fn ratios(&self, ratio: NmRatio) -> Vec<Option<NmRatio>> {
        self.sparse_flags()
            .into_iter()
            .map(|s| if s { Some(ratio) } else { None })
            .collect()
    }

    /// Forward pass: logits `[batch, n_classes]`.
    pub fn forward(&self, params: &[Tensor], x: &Tensor) -> Tensor {
        let mut h = x.clone().reshape(&[x.rows_2d(), x.last_dim()]);
        for l in 0..self.n_layers() {
            let w = &params[2 * l];
            let b = &params[2 * l + 1];
            h = matmul(&h, w);
            add_bias(&mut h, b);
            if l != self.n_layers() - 1 {
                h = relu(&h);
            }
        }
        h
    }

    /// Mean cross-entropy loss + exact gradients w.r.t. every parameter.
    ///
    /// Returns `(loss, grads)` where `grads[i]` matches `params[i]`'s shape.
    pub fn loss_and_grad(
        &self,
        params: &[Tensor],
        x: &Tensor,
        labels: &[usize],
    ) -> (f64, Vec<Tensor>) {
        let n_layers = self.n_layers();
        // forward, caching pre-activations' post-ReLU values
        let x2 = x.clone().reshape(&[x.rows_2d(), x.last_dim()]);
        let mut acts: Vec<Tensor> = Vec::with_capacity(n_layers + 1);
        acts.push(x2);
        for l in 0..n_layers {
            let mut h = matmul(acts.last().unwrap(), &params[2 * l]);
            add_bias(&mut h, &params[2 * l + 1]);
            if l != n_layers - 1 {
                h = relu(&h);
            }
            acts.push(h);
        }
        let logits = acts.last().unwrap();
        let (loss, mut delta) = cross_entropy_with_grad(logits, labels);

        // backward
        let mut grads: Vec<Tensor> = (0..self.n_params())
            .map(|_| Tensor::zeros(&[0]))
            .collect();
        for l in (0..n_layers).rev() {
            let a_in = &acts[l];
            // dW = a_inᵀ @ delta ; db = colsum(delta)
            grads[2 * l] = matmul_at(a_in, &delta);
            let (rows, cols) = delta.as_2d();
            let mut db = Tensor::zeros(&[cols]);
            for r in 0..rows {
                for c in 0..cols {
                    db.data_mut()[c] += delta.data()[r * cols + c];
                }
            }
            grads[2 * l + 1] = db;
            if l > 0 {
                // dA = delta @ Wᵀ, gated by the ReLU mask of a_in
                let mut da = matmul_bt(&delta, &params[2 * l]);
                for (d, &a) in da.data_mut().iter_mut().zip(a_in.data()) {
                    if a <= 0.0 {
                        *d = 0.0;
                    }
                }
                delta = da;
            }
        }
        (loss, grads)
    }

    /// Classification accuracy on a batch.
    pub fn accuracy(&self, params: &[Tensor], x: &Tensor, labels: &[usize]) -> f64 {
        let logits = self.forward(params, x);
        let preds = argmax_rows(&logits);
        let correct = preds.iter().zip(labels).filter(|(p, y)| p == y).count();
        correct as f64 / labels.len().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::Cases;

    #[test]
    fn shapes_and_flags() {
        let mlp = Mlp::new(8, &[16, 12], 3);
        assert_eq!(mlp.n_layers(), 3);
        assert_eq!(mlp.n_params(), 6);
        assert_eq!(
            mlp.sparse_flags(),
            vec![true, false, true, false, false, false]
        );
        let p = mlp.init(&mut Pcg64::new(0));
        assert_eq!(p[0].shape(), &[8, 16]);
        assert_eq!(p[5].shape(), &[3]);
    }

    #[test]
    fn forward_shapes() {
        let mlp = Mlp::new(8, &[16], 3);
        let p = mlp.init(&mut Pcg64::new(1));
        let x = Tensor::randn(&[5, 8], &mut Pcg64::new(2), 0.0, 1.0);
        let y = mlp.forward(&p, &x);
        assert_eq!(y.shape(), &[5, 3]);
    }

    #[test]
    fn gradients_match_finite_differences() {
        Cases::new(4).run(|rng, _| {
            let mlp = Mlp::new(4, &[6], 3);
            let params = mlp.init(rng);
            let x = Tensor::randn(&[3, 4], rng, 0.0, 1.0);
            let labels = vec![rng.below(3), rng.below(3), rng.below(3)];
            let (loss, grads) = mlp.loss_and_grad(&params, &x, &labels);
            let eps = 1e-3f32;
            // probe a handful of random coordinates of each tensor
            for (pi, g) in grads.iter().enumerate() {
                for _probe in 0..4 {
                    let idx = rng.below(g.numel());
                    let mut pp = params.clone();
                    pp[pi].data_mut()[idx] += eps;
                    let (l2, _) = mlp.loss_and_grad(&pp, &x, &labels);
                    let fd = (l2 - loss) / eps as f64;
                    let an = g.data()[idx] as f64;
                    assert!(
                        (fd - an).abs() < 5e-2 * (1.0 + an.abs()),
                        "param {pi} idx {idx}: fd {fd} vs {an}"
                    );
                }
            }
        });
    }

    #[test]
    fn training_reduces_loss() {
        let mut rng = Pcg64::new(3);
        let mlp = Mlp::new(10, &[32], 4);
        let mut params = mlp.init(&mut rng);
        // fixed synthetic batch: learn to classify by cluster
        let x = Tensor::randn(&[64, 10], &mut rng, 0.0, 1.0);
        let labels: Vec<usize> = (0..64).map(|i| i % 4).collect();
        let (first, _) = mlp.loss_and_grad(&params, &x, &labels);
        for _ in 0..200 {
            let (_, grads) = mlp.loss_and_grad(&params, &x, &labels);
            for (p, g) in params.iter_mut().zip(&grads) {
                crate::tensor::axpy(p, -0.5, g);
            }
        }
        let (last, _) = mlp.loss_and_grad(&params, &x, &labels);
        assert!(last < first * 0.5, "{first} -> {last}");
        assert!(mlp.accuracy(&params, &x, &labels) > 0.8);
    }
}
