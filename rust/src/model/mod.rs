//! Pure-Rust MLP with exact backprop — the fast-CPU substrate for the
//! many-seed / many-step experiments (Table 1 traces, Theorem-1 checks,
//! Fig. 7's switch-ratio sweep) where per-step PJRT dispatch would dominate.
//!
//! The layout mirrors `python/compile/models.mlp`: parameters are the flat
//! ordered list `[fc0_w, fc0_b, fc1_w, fc1_b, …]` with hidden weight
//! matrices sparse-eligible and the final layer dense, so recipe code (and
//! the manifest conventions) transfer unchanged between the two engines.

use crate::rng::Pcg64;
use crate::sparsity::{packed_matmul, NmRatio, PackedParam};
use crate::tensor::{
    accuracy_from_logits, add_bias, cross_entropy_with_grad, matmul, matmul_at, matmul_bt, relu,
    Tensor,
};

/// An MLP classifier: `in_dim → hidden… → n_classes`, ReLU activations.
#[derive(Debug, Clone)]
pub struct Mlp {
    pub sizes: Vec<usize>,
}

impl Mlp {
    pub fn new(in_dim: usize, hidden: &[usize], n_classes: usize) -> Self {
        let mut sizes = vec![in_dim];
        sizes.extend_from_slice(hidden);
        sizes.push(n_classes);
        Self { sizes }
    }

    pub fn n_layers(&self) -> usize {
        self.sizes.len() - 1
    }

    /// Number of parameter tensors (2 per layer: weight, bias).
    pub fn n_params(&self) -> usize {
        2 * self.n_layers()
    }

    /// Total scalar parameter count.
    pub fn dim(&self) -> usize {
        self.init(&mut Pcg64::new(0)).iter().map(|t| t.numel()).sum()
    }

    /// Fan-in-scaled init matching `models._init_param` (weights ~
    /// N(0, 1/fan_in), biases zero).
    pub fn init(&self, rng: &mut Pcg64) -> Vec<Tensor> {
        let mut out = Vec::with_capacity(self.n_params());
        for l in 0..self.n_layers() {
            let (fan_in, fan_out) = (self.sizes[l], self.sizes[l + 1]);
            let scale = 1.0 / (fan_in as f32).sqrt();
            out.push(Tensor::randn(&[fan_in, fan_out], rng, 0.0, scale));
            out.push(Tensor::zeros(&[fan_out]));
        }
        out
    }

    /// Sparse-eligibility per parameter tensor: hidden weights yes, last
    /// layer and biases no — matching the Python model zoo.
    pub fn sparse_flags(&self) -> Vec<bool> {
        let n = self.n_layers();
        (0..self.n_params())
            .map(|i| i % 2 == 0 && i / 2 != n - 1)
            .collect()
    }

    /// Uniform ratio vector from the flags (`None` = dense tensor).
    pub fn ratios(&self, ratio: NmRatio) -> Vec<Option<NmRatio>> {
        self.sparse_flags()
            .into_iter()
            .map(|s| if s { Some(ratio) } else { None })
            .collect()
    }

    /// Forward pass: logits `[batch, n_classes]`.
    pub fn forward(&self, params: &[Tensor], x: &Tensor) -> Tensor {
        let reshaped;
        let x2d: &Tensor = if x.ndim() == 2 {
            x // layer 0 only reads its input — no defensive copy
        } else {
            reshaped = x.clone().reshape(&[x.rows_2d(), x.last_dim()]);
            &reshaped
        };
        let mut h: Option<Tensor> = None;
        for l in 0..self.n_layers() {
            let input = h.as_ref().unwrap_or(x2d);
            let mut next = matmul(input, &params[2 * l]);
            add_bias(&mut next, &params[2 * l + 1]);
            if l != self.n_layers() - 1 {
                next = relu(&next);
            }
            h = Some(next);
        }
        h.expect("MLP has at least one layer")
    }

    /// Forward pass over **packed** weights: logits `[batch, n_classes]`.
    ///
    /// The inference twin of [`Mlp::forward`]: hidden weights stored as
    /// [`PackedNmTensor`](crate::sparsity::PackedNmTensor) run the sparse
    /// kernels ([`packed_matmul`]) that skip pruned slots, dense parameters
    /// run the ordinary dense path. Output is bit-for-bit identical to
    /// `forward` over the dense *masked* weights on finite inputs — the
    /// integration suite (`rust/tests/packed_inference.rs`) holds the two
    /// equal across batch sizes.
    pub fn forward_packed(&self, params: &[PackedParam], x: &Tensor) -> Tensor {
        assert_eq!(params.len(), self.n_params(), "packed param arity");
        let reshaped;
        let x2d: &Tensor = if x.ndim() == 2 {
            x // layer 0 only reads its input — no defensive copy
        } else {
            reshaped = x.clone().reshape(&[x.rows_2d(), x.last_dim()]);
            &reshaped
        };
        let mut h: Option<Tensor> = None;
        for l in 0..self.n_layers() {
            let input = h.as_ref().unwrap_or(x2d);
            let b = params[2 * l + 1]
                .as_dense()
                .expect("bias tensors are never packed");
            let mut next = match &params[2 * l] {
                PackedParam::Dense(w) => matmul(input, w),
                PackedParam::Packed(w) => packed_matmul(input, w),
            };
            add_bias(&mut next, b);
            if l != self.n_layers() - 1 {
                next = relu(&next);
            }
            h = Some(next);
        }
        h.expect("MLP has at least one layer")
    }

    /// The dense **masked** parameter list: `Π ⊙ w` on sparse-eligible
    /// tensors (via [`crate::sparsity::apply_nm`]), clones elsewhere — the
    /// baseline every packed path is held bit-identical to.
    pub fn masked_params(&self, params: &[Tensor], ratio: NmRatio) -> Vec<Tensor> {
        params
            .iter()
            .zip(self.sparse_flags())
            .map(|(p, s)| if s { crate::sparsity::apply_nm(p, ratio) } else { p.clone() })
            .collect()
    }

    /// Pack trained parameters for inference: hidden weights are compressed
    /// at `ratio` (the same selection rule training masks used), biases and
    /// the final layer stay dense. The one-time export step before serving —
    /// see [`crate::coordinator::serve::BatchServer`].
    pub fn pack_params(&self, params: &[Tensor], ratio: NmRatio) -> Vec<PackedParam> {
        crate::sparsity::pack_params(params, &self.ratios(ratio))
    }

    /// Classification accuracy of a packed model on a batch.
    pub fn accuracy_packed(&self, params: &[PackedParam], x: &Tensor, labels: &[usize]) -> f64 {
        accuracy_from_logits(&self.forward_packed(params, x), labels)
    }

    /// Mean cross-entropy loss + exact gradients w.r.t. every parameter.
    ///
    /// Returns `(loss, grads)` where `grads[i]` matches `params[i]`'s shape.
    pub fn loss_and_grad(
        &self,
        params: &[Tensor],
        x: &Tensor,
        labels: &[usize],
    ) -> (f64, Vec<Tensor>) {
        let n_layers = self.n_layers();
        // forward, caching pre-activations' post-ReLU values
        let x2 = x.clone().reshape(&[x.rows_2d(), x.last_dim()]);
        let mut acts: Vec<Tensor> = Vec::with_capacity(n_layers + 1);
        acts.push(x2);
        for l in 0..n_layers {
            let mut h = matmul(acts.last().unwrap(), &params[2 * l]);
            add_bias(&mut h, &params[2 * l + 1]);
            if l != n_layers - 1 {
                h = relu(&h);
            }
            acts.push(h);
        }
        let logits = acts.last().unwrap();
        let (loss, mut delta) = cross_entropy_with_grad(logits, labels);

        // backward
        let mut grads: Vec<Tensor> = (0..self.n_params())
            .map(|_| Tensor::zeros(&[0]))
            .collect();
        for l in (0..n_layers).rev() {
            let a_in = &acts[l];
            // dW = a_inᵀ @ delta ; db = colsum(delta)
            grads[2 * l] = matmul_at(a_in, &delta);
            let (rows, cols) = delta.as_2d();
            let mut db = Tensor::zeros(&[cols]);
            for r in 0..rows {
                for c in 0..cols {
                    db.data_mut()[c] += delta.data()[r * cols + c];
                }
            }
            grads[2 * l + 1] = db;
            if l > 0 {
                // dA = delta @ Wᵀ, gated by the ReLU mask of a_in
                let mut da = matmul_bt(&delta, &params[2 * l]);
                for (d, &a) in da.data_mut().iter_mut().zip(a_in.data()) {
                    if a <= 0.0 {
                        *d = 0.0;
                    }
                }
                delta = da;
            }
        }
        (loss, grads)
    }

    /// Classification accuracy on a batch.
    pub fn accuracy(&self, params: &[Tensor], x: &Tensor, labels: &[usize]) -> f64 {
        accuracy_from_logits(&self.forward(params, x), labels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::Cases;

    #[test]
    fn shapes_and_flags() {
        let mlp = Mlp::new(8, &[16, 12], 3);
        assert_eq!(mlp.n_layers(), 3);
        assert_eq!(mlp.n_params(), 6);
        assert_eq!(
            mlp.sparse_flags(),
            vec![true, false, true, false, false, false]
        );
        let p = mlp.init(&mut Pcg64::new(0));
        assert_eq!(p[0].shape(), &[8, 16]);
        assert_eq!(p[5].shape(), &[3]);
    }

    #[test]
    fn forward_shapes() {
        let mlp = Mlp::new(8, &[16], 3);
        let p = mlp.init(&mut Pcg64::new(1));
        let x = Tensor::randn(&[5, 8], &mut Pcg64::new(2), 0.0, 1.0);
        let y = mlp.forward(&p, &x);
        assert_eq!(y.shape(), &[5, 3]);
    }

    #[test]
    fn gradients_match_finite_differences() {
        Cases::new(4).run(|rng, _| {
            let mlp = Mlp::new(4, &[6], 3);
            let params = mlp.init(rng);
            let x = Tensor::randn(&[3, 4], rng, 0.0, 1.0);
            let labels = vec![rng.below(3), rng.below(3), rng.below(3)];
            let (loss, grads) = mlp.loss_and_grad(&params, &x, &labels);
            let eps = 1e-3f32;
            // probe a handful of random coordinates of each tensor
            for (pi, g) in grads.iter().enumerate() {
                for _probe in 0..4 {
                    let idx = rng.below(g.numel());
                    let mut pp = params.clone();
                    pp[pi].data_mut()[idx] += eps;
                    let (l2, _) = mlp.loss_and_grad(&pp, &x, &labels);
                    let fd = (l2 - loss) / eps as f64;
                    let an = g.data()[idx] as f64;
                    assert!(
                        (fd - an).abs() < 5e-2 * (1.0 + an.abs()),
                        "param {pi} idx {idx}: fd {fd} vs {an}"
                    );
                }
            }
        });
    }

    #[test]
    fn packed_forward_matches_dense_masked() {
        let mlp = Mlp::new(16, &[24, 16], 5);
        let mut rng = Pcg64::new(4);
        let params = mlp.init(&mut rng);
        let ratio = NmRatio::new(2, 4);
        let masked = mlp.masked_params(&params, ratio);
        let packed = mlp.pack_params(&params, ratio);
        for batch in [1usize, 5, 8, 11] {
            let x = Tensor::randn(&[batch, 16], &mut rng, 0.0, 1.0);
            let dense = mlp.forward(&masked, &x);
            let sparse = mlp.forward_packed(&packed, &x);
            assert_eq!(dense, sparse, "batch {batch}");
            let labels: Vec<usize> = (0..batch).map(|i| i % 5).collect();
            assert_eq!(
                mlp.accuracy(&masked, &x, &labels),
                mlp.accuracy_packed(&packed, &x, &labels)
            );
        }
    }

    #[test]
    fn training_reduces_loss() {
        let mut rng = Pcg64::new(3);
        let mlp = Mlp::new(10, &[32], 4);
        let mut params = mlp.init(&mut rng);
        // fixed synthetic batch: learn to classify by cluster
        let x = Tensor::randn(&[64, 10], &mut rng, 0.0, 1.0);
        let labels: Vec<usize> = (0..64).map(|i| i % 4).collect();
        let (first, _) = mlp.loss_and_grad(&params, &x, &labels);
        for _ in 0..200 {
            let (_, grads) = mlp.loss_and_grad(&params, &x, &labels);
            for (p, g) in params.iter_mut().zip(&grads) {
                crate::tensor::axpy(p, -0.5, g);
            }
        }
        let (last, _) = mlp.loss_and_grad(&params, &x, &labels);
        assert!(last < first * 0.5, "{first} -> {last}");
        assert!(mlp.accuracy(&params, &x, &labels) > 0.8);
    }
}
