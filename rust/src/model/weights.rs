//! Storage-form dispatch shared by the attention model family
//! ([`super::TokenEncoder`], [`super::TokenDecoder`]): the same core
//! forward/backward code runs over dense tensors or packed N:M weights,
//! with only the projection matmuls swapping kernels. Keeping the dispatch
//! in one place is what makes the packed paths **bit-for-bit** identical
//! to the dense masked oracle by construction — there is exactly one
//! implementation of everything that is not a matmul.

use crate::sparsity::{
    packed_matmul, packed_matmul_at_into, packed_matmul_bt_into, PackedGrad, PackedParam,
};
use crate::tensor::{matmul, matmul_at, matmul_bt, Tensor};

/// Storage-form dispatch for the core forward/backward: the three matmul
/// shapes a projection participates in either run the dense kernels or the
/// packed N:M kernels. Only the sparse-eligible block projections ever
/// differ; every dense-always parameter (embeddings, biases, LayerNorm
/// affines, head) reads through [`WeightsView::tensor`].
pub(crate) enum WeightsView<'a> {
    Dense(&'a [Tensor]),
    Packed {
        params: &'a [PackedParam],
        /// Decoded column indices per packed parameter (`None` for dense).
        cols: &'a [Option<Vec<u32>>],
    },
}

impl<'a> WeightsView<'a> {
    /// Parameter `i` as a dense tensor (panics if it is packed — only ever
    /// called for the dense-always parameters).
    pub(crate) fn tensor(&self, i: usize) -> &Tensor {
        match self {
            WeightsView::Dense(p) => &p[i],
            WeightsView::Packed { params, .. } => params[i]
                .as_dense()
                // nm-lint: allow(panic-freedom): only the dense-always parameter indices reach this accessor — packing eligibility is fixed by sparse_flags at pack time
                .expect("embeddings, biases, norms and the head are never packed"),
        }
    }

    /// `h @ W_i` — forward projection.
    pub(crate) fn matmul(&self, h: &Tensor, i: usize) -> Tensor {
        match self {
            WeightsView::Dense(p) => matmul(h, &p[i]),
            WeightsView::Packed { params, .. } => match &params[i] {
                PackedParam::Dense(w) => matmul(h, w),
                PackedParam::Packed(w) => packed_matmul(h, w),
            },
        }
    }

    /// `delta @ W_iᵀ` — the activation gradient through projection `i`.
    pub(crate) fn matmul_bt(&self, delta: &Tensor, i: usize) -> Tensor {
        match self {
            WeightsView::Dense(p) => matmul_bt(delta, &p[i]),
            WeightsView::Packed { params, cols } => match &params[i] {
                PackedParam::Dense(w) => matmul_bt(delta, w),
                PackedParam::Packed(w) => {
                    // nm-lint: allow(panic-freedom): cols_cache builds an entry for every packed param
                    let ci = cols[i].as_ref().expect("packed param lacks cols cache");
                    let (rows, _) = delta.as_2d();
                    let mut out = Tensor::zeros(&[rows, w.shape()[0]]);
                    packed_matmul_bt_into(delta, w, ci, &mut out);
                    out
                }
            },
        }
    }

    /// `aᵀ @ delta` — the weight gradient of projection `i` (compact on the
    /// packed side: pruned coordinates are never materialized).
    pub(crate) fn grad_w(&self, a: &Tensor, delta: &Tensor, i: usize) -> PackedGrad {
        match self {
            WeightsView::Dense(_) => PackedGrad::Dense(matmul_at(a, delta)),
            WeightsView::Packed { params, cols } => match &params[i] {
                PackedParam::Dense(_) => PackedGrad::Dense(matmul_at(a, delta)),
                PackedParam::Packed(w) => {
                    // nm-lint: allow(panic-freedom): cols_cache builds an entry for every packed param
                    let ci = cols[i].as_ref().expect("packed param lacks cols cache");
                    let mut gv = vec![0f32; w.n_values()];
                    packed_matmul_at_into(a, delta, w, ci, &mut gv);
                    PackedGrad::Compact(gv)
                }
            },
        }
    }
}

/// Column-sum of a 2-D tensor (the bias gradient), identical accumulation
/// order to the MLP's inline loop.
pub(crate) fn colsum(t: &Tensor) -> Tensor {
    let (rows, cols) = t.as_2d();
    let mut out = Tensor::zeros(&[cols]);
    let td = t.data();
    let od = out.data_mut();
    for r in 0..rows {
        for (o, &v) in od.iter_mut().zip(&td[r * cols..(r + 1) * cols]) {
            *o += v;
        }
    }
    out
}
