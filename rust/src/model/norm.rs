//! LayerNorm with an **exact analytic backward** — the missing primitive
//! behind the legacy separate-QKV + LayerNorm manifest layouts (open since
//! PR 5, closed by [`super::TokenDecoder`]).
//!
//! Forward, per row over the trailing dimension `d` (μ and σ² accumulated
//! in f64, ascending index order — the fixed accumulation order IS the
//! bit-identity contract for this module):
//!
//! ```text
//!   x̂_j = (x_j − μ) / √(σ² + ε)        y_j = x̂_j · γ_j + β_j
//! ```
//!
//! Backward, in closed form (the standard LayerNorm Jacobian; `m1`/`m2`
//! are per-row means of `dŷ` and `dŷ ⊙ x̂` in f64):
//!
//! ```text
//!   dx̂_j = dy_j · γ_j
//!   dx_j  = (dx̂_j − m1 − x̂_j · m2) / √(σ² + ε)
//!   dγ_j  = Σ_rows dy_j · x̂_j          dβ_j = Σ_rows dy_j
//! ```
//!
//! `rust/tests/decoder_generation.rs` holds [`layer_norm_backward`] to
//! finite-difference checks. Because the normalization is **per-row**, a
//! row's output depends on nothing but that row — which is what lets the
//! KV-cached incremental decode ([`super::TokenDecoder::decode_step`])
//! reproduce the full-sequence forward bit-for-bit.

use crate::tensor::Tensor;

/// The ε inside the √ of every LayerNorm in the model zoo (the GPT-2 /
/// BERT convention).
pub const LN_EPS: f32 = 1e-5;

/// Forward byproducts the backward replays: the normalized activations
/// and the per-row `1/√(σ²+ε)` (kept in f64 so forward and backward agree
/// to the last bit on what was divided by).
pub struct LnCache {
    /// `x̂` — normalized pre-affine activations `[rows, d]`.
    pub xhat: Tensor,
    /// Per-row inverse standard deviation (f64, the forward's own value).
    pub inv_std: Vec<f64>,
}

/// Row-wise LayerNorm over the trailing dimension: `y = x̂ ⊙ γ + β` with
/// the cache the exact backward needs. `gamma`/`beta` are `[d]` where `d`
/// is `x`'s trailing dimension.
pub fn layer_norm(x: &Tensor, gamma: &Tensor, beta: &Tensor) -> (Tensor, LnCache) {
    let (rows, d) = x.as_2d();
    assert_eq!(gamma.numel(), d, "layer_norm: gamma length vs trailing dim");
    assert_eq!(beta.numel(), d, "layer_norm: beta length vs trailing dim");
    assert!(d >= 1, "layer_norm: empty trailing dimension");
    let xd = x.data();
    let gd = gamma.data();
    let bd = beta.data();
    let mut y = Tensor::zeros(&[rows, d]);
    let mut xhat = Tensor::zeros(&[rows, d]);
    let mut inv_std = vec![0f64; rows];
    let yd = y.data_mut();
    let hd = xhat.data_mut();
    for r in 0..rows {
        let row = &xd[r * d..(r + 1) * d];
        // μ and σ² in f64, ascending j — the pinned accumulation order
        let mut sum = 0f64;
        for &v in row {
            sum += v as f64;
        }
        let mean = sum / d as f64;
        let mut var_sum = 0f64;
        for &v in row {
            let c = v as f64 - mean;
            var_sum += c * c;
        }
        let istd = 1.0 / (var_sum / d as f64 + LN_EPS as f64).sqrt();
        inv_std[r] = istd;
        let hrow = &mut hd[r * d..(r + 1) * d];
        let yrow = &mut yd[r * d..(r + 1) * d];
        for j in 0..d {
            let xh = ((row[j] as f64 - mean) * istd) as f32;
            hrow[j] = xh;
            yrow[j] = xh * gd[j] + bd[j];
        }
    }
    (y, LnCache { xhat, inv_std })
}

/// Exact analytic backward of [`layer_norm`]: `(dx, dγ, dβ)` from the
/// upstream gradient `dy` and the forward cache. Per-row means `m1`/`m2`
/// accumulate in f64 ascending; the parameter gradients accumulate rows
/// ascending (the same convention as the model zoo's bias column-sums).
pub fn layer_norm_backward(
    dy: &Tensor,
    gamma: &Tensor,
    cache: &LnCache,
) -> (Tensor, Tensor, Tensor) {
    let (rows, d) = dy.as_2d();
    assert_eq!(cache.xhat.shape(), &[rows, d], "layer_norm_backward: cache shape");
    assert_eq!(cache.inv_std.len(), rows, "layer_norm_backward: cache rows");
    assert_eq!(gamma.numel(), d, "layer_norm_backward: gamma length");
    let dyd = dy.data();
    let gd = gamma.data();
    let hd = cache.xhat.data();
    let mut dx = Tensor::zeros(&[rows, d]);
    let mut dgamma = Tensor::zeros(&[d]);
    let mut dbeta = Tensor::zeros(&[d]);
    let dxd = dx.data_mut();
    let dgd = dgamma.data_mut();
    let dbd = dbeta.data_mut();
    for r in 0..rows {
        let dyrow = &dyd[r * d..(r + 1) * d];
        let hrow = &hd[r * d..(r + 1) * d];
        let istd = cache.inv_std[r];
        // m1 = mean(dx̂), m2 = mean(dx̂ ⊙ x̂) in f64, ascending j
        let mut m1 = 0f64;
        let mut m2 = 0f64;
        for j in 0..d {
            let dxh = (dyrow[j] * gd[j]) as f64;
            m1 += dxh;
            m2 += dxh * hrow[j] as f64;
        }
        m1 /= d as f64;
        m2 /= d as f64;
        let dxrow = &mut dxd[r * d..(r + 1) * d];
        for j in 0..d {
            let dxh = (dyrow[j] * gd[j]) as f64;
            dxrow[j] = ((dxh - m1 - hrow[j] as f64 * m2) * istd) as f32;
            dgd[j] += dyrow[j] * hrow[j];
            dbd[j] += dyrow[j];
        }
    }
    (dx, dgamma, dbeta)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn forward_normalizes_rows() {
        let mut rng = Pcg64::new(11);
        let x = Tensor::randn(&[4, 16], &mut rng, 3.0, 2.0);
        let gamma = Tensor::full(&[16], 1.0);
        let beta = Tensor::zeros(&[16]);
        let (y, cache) = layer_norm(&x, &gamma, &beta);
        assert_eq!(y.shape(), &[4, 16]);
        let yd = y.data();
        for r in 0..4 {
            let row = &yd[r * 16..(r + 1) * 16];
            let mean: f64 = row.iter().map(|&v| v as f64).sum::<f64>() / 16.0;
            let var: f64 = row.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / 16.0;
            assert!(mean.abs() < 1e-5, "row {r} mean {mean}");
            assert!((var - 1.0).abs() < 1e-3, "row {r} var {var}");
            assert!(cache.inv_std[r] > 0.0);
        }
        // identity affine keeps y == x̂
        assert_eq!(y.data(), cache.xhat.data());
    }

    #[test]
    fn affine_applies_per_feature() {
        let x = Tensor::new(&[1, 4], vec![1.0, 2.0, 3.0, 4.0]);
        let gamma = Tensor::new(&[4], vec![2.0, 2.0, 2.0, 2.0]);
        let beta = Tensor::new(&[4], vec![0.5, 0.5, 0.5, 0.5]);
        let (y, cache) = layer_norm(&x, &gamma, &beta);
        for (j, &v) in y.data().iter().enumerate() {
            let expect = cache.xhat.data()[j] * 2.0 + 0.5;
            assert_eq!(v, expect, "feature {j}");
        }
    }

    #[test]
    fn constant_rows_stay_finite() {
        // σ² = 0: the ε keeps the division finite and x̂ exactly zero
        let x = Tensor::full(&[2, 8], 7.0);
        let gamma = Tensor::full(&[8], 1.5);
        let beta = Tensor::full(&[8], -0.25);
        let (y, cache) = layer_norm(&x, &gamma, &beta);
        assert!(y.data().iter().all(|v| v.is_finite()));
        assert!(cache.xhat.data().iter().all(|&v| v == 0.0));
        assert!(y.data().iter().all(|&v| v == -0.25));
        let dy = Tensor::full(&[2, 8], 1.0);
        let (dx, dg, db) = layer_norm_backward(&dy, &gamma, &cache);
        assert!(dx.data().iter().all(|v| v.is_finite()));
        assert!(dg.data().iter().all(|&v| v == 0.0), "dγ over zero x̂");
        assert!(db.data().iter().all(|&v| v == 2.0), "dβ sums the rows");
    }

    /// The analytic backward against central finite differences of the
    /// scalar probe L = Σ w ⊙ layer_norm(x) for fixed random w, over x,
    /// γ and β. (The heavier fd suite lives in
    /// `rust/tests/decoder_generation.rs`.)
    #[test]
    fn backward_matches_finite_differences() {
        let mut rng = Pcg64::new(12);
        let x = Tensor::randn(&[3, 5], &mut rng, 0.0, 1.0);
        let gamma = Tensor::randn(&[5], &mut rng, 1.0, 0.2);
        let beta = Tensor::randn(&[5], &mut rng, 0.0, 0.2);
        let w = Tensor::randn(&[3, 5], &mut rng, 0.0, 1.0);
        let probe = |x: &Tensor, g: &Tensor, b: &Tensor| -> f64 {
            let (y, _) = layer_norm(x, g, b);
            y.data().iter().zip(w.data()).map(|(&a, &c)| a as f64 * c as f64).sum()
        };
        let (_, cache) = layer_norm(&x, &gamma, &beta);
        let (dx, dg, db) = layer_norm_backward(&w, &gamma, &cache);
        let eps = 1e-2f32;
        let check = |analytic: f32, plus: f64, minus: f64, what: &str| {
            let fd = (plus - minus) / (2.0 * eps as f64);
            let tol = 1e-2 * (1.0 + fd.abs());
            assert!(
                (analytic as f64 - fd).abs() < tol,
                "{what}: analytic {analytic} vs fd {fd}"
            );
        };
        for i in 0..x.numel() {
            let mut xp = x.clone();
            xp.data_mut()[i] += eps;
            let mut xm = x.clone();
            xm.data_mut()[i] -= eps;
            check(dx.data()[i], probe(&xp, &gamma, &beta), probe(&xm, &gamma, &beta), "dx");
        }
        for i in 0..gamma.numel() {
            let mut gp = gamma.clone();
            gp.data_mut()[i] += eps;
            let mut gm = gamma.clone();
            gm.data_mut()[i] -= eps;
            check(dg.data()[i], probe(&x, &gp, &beta), probe(&x, &gm, &beta), "dγ");
            let mut bp = beta.clone();
            bp.data_mut()[i] += eps;
            let mut bm = beta.clone();
            bm.data_mut()[i] -= eps;
            check(db.data()[i], probe(&x, &gamma, &bp), probe(&x, &gamma, &bm), "dβ");
        }
    }
}
