//! `cargo bench --bench substrate` — pure-Rust hot-path kernels: N:M mask
//! selection, the blocked matmuls, fused optimizer updates, the AutoSwitch
//! window, the recipe-engine step-throughput suite (fused vs unfused
//! reference on the Table-1 workload shapes, recorded to
//! `BENCH_recipes.json`), the packed-inference suite (compressed N:M
//! forward vs dense masked forward, recorded to `BENCH_inference.json`),
//! the packed fine-tune suite (compact-gradient frozen-mask step vs dense
//! masked step, recorded to `BENCH_finetune.json`), the packed-attention
//! suite (compressed-projection [`TokenEncoder`] forward vs dense masked,
//! recorded to `BENCH_attention.json`), the streaming-driver suite
//! (TrainDriver epoch vs manual batch-at-a-time loop, recorded to
//! `BENCH_train.json`), the online-serving suite (closed-loop seeded
//! traffic through the dynamic-batching `ServeFrontend` vs solo sequential
//! serving, with exact-order latency percentiles, recorded to
//! `BENCH_serving.json`), and the autoregressive-generation suite
//! (KV-cached packed decoding through `BatchGenerator` vs the dense masked
//! full-recompute oracle, recorded to `BENCH_generation.json`).
//!
//! Pass `--smoke` (or set `BENCH_SMOKE=1`) for a reduced-iteration run that
//! still executes every bit-equality gate and writes all seven JSON files —
//! the CI smoke job uses it to keep the comparison suites honest.

use step_nm::coordinator::frontend::{
    FrontendConfig, FrontendStats, LatencyRecord, ServeFrontend, SubmitError,
};
use step_nm::coordinator::{
    BatchGenerator, BatchServer, DriverConfig, FinetuneSession, GenerateConfig, TrainDriver,
};
use step_nm::autoswitch::{AutoSwitch, SwitchPolicy, SwitchStat, ZOption};
use step_nm::bench::{
    print_header, write_comparison_json, write_comparison_json_with, Comparison, Harness,
};
use step_nm::data::{Batch, BatchX, BatchY, CifarLike, Dataset, MiniBatchStream};
use step_nm::model::{Mlp, SparseModel, TokenDecoder, TokenEncoder};
use step_nm::optim::{
    adam_update, sgdm_update, step_phase2_update, AdamHp, PureRecipe, RecipeState,
};
use step_nm::rng::Pcg64;
use step_nm::sparsity::{
    apply_nm_inplace, nm_mask_into, DecaySchedule, NmRatio, PackedNmTensor, PackedParam,
};
use step_nm::tensor::{argmax_rows, matmul, matmul_at, matmul_bt, Tensor};

/// An MLP-shaped parameter stack: `[w0, b0, w1, b1, …]`, hidden weights
/// sparse-eligible at 2:4, final layer + biases dense — the layout every
/// Table-1 analog task trains.
fn workload(
    rng: &mut Pcg64,
    sizes: &[usize],
) -> (Vec<Tensor>, Vec<Option<NmRatio>>, Vec<Tensor>) {
    let mut params = Vec::new();
    let mut ratios = Vec::new();
    for l in 0..sizes.len() - 1 {
        params.push(Tensor::randn(&[sizes[l], sizes[l + 1]], rng, 0.0, 0.5));
        ratios.push((l != sizes.len() - 2).then_some(NmRatio::new(2, 4)));
        params.push(Tensor::randn(&[sizes[l + 1]], rng, 0.0, 0.1));
        ratios.push(None);
    }
    let grads = params
        .iter()
        .map(|p| Tensor::randn(p.shape(), rng, 0.0, 0.1))
        .collect();
    (params, ratios, grads)
}

/// Fused vs reference step throughput for every recipe on one workload.
/// The gradient closure returns a precomputed clone on both paths; its
/// measured cost is subtracted from both means, so the recorded numbers
/// isolate the engine (masks + forward weights + update + telemetry),
/// not the loss closure.
fn bench_recipe_steps(
    h: Harness,
    rng: &mut Pcg64,
    shape_name: &str,
    sizes: &[usize],
    out: &mut Vec<Comparison>,
) {
    print_header(&format!("recipe step throughput — {shape_name} {sizes:?}"));
    let (params, ratios, grads) = workload(rng, sizes);
    let total: usize = params.iter().map(Tensor::numel).sum();
    // Both paths pay one grads.clone() per step inside the timed region (the
    // closure must return owned grads). Measure that constant and subtract
    // it from both means so the recorded ratio reflects the ENGINE, not the
    // shared closure cost; floor at 5% of the raw mean to bound noise.
    let clone_overhead = h.run("grads.clone() baseline", || grads.clone()).mean();
    let engine_mean = |raw: f64| (raw - clone_overhead).max(raw * 0.05);
    let recipes: [(&str, PureRecipe, bool); 8] = [
        ("dense_adam", PureRecipe::DenseAdam, false),
        ("dense_sgdm", PureRecipe::DenseSgdm { momentum: 0.9 }, false),
        ("srste_adam", PureRecipe::SrSteAdam { lam: 2e-4 }, false),
        ("srste_sgdm", PureRecipe::SrSteSgdm { lam: 2e-4, momentum: 0.9 }, false),
        ("asp", PureRecipe::Asp, false),
        // lam = 2e-4 like the Table-1 runs, so the STEP rows time the real
        // workload (lam = 0 would skip the SR-STE term in the fused kernels)
        ("step_phase2", PureRecipe::Step { lam: 2e-4 }, true),
        ("step_v_updated", PureRecipe::StepVarianceUpdated { lam: 2e-4 }, true),
        ("decaying_mask", PureRecipe::DecayingMask { lam: 2e-4 }, false),
    ];
    for (name, recipe, switch) in recipes {
        let mut st0 =
            RecipeState::new(recipe, &params, ratios.clone(), 1e-3, AdamHp::default());
        if matches!(recipe, PureRecipe::DecayingMask { .. }) {
            st0 = st0.with_schedule(DecaySchedule::new(4, 2, 0, 1_000_000));
        }
        // settle into steady state (and cross the STEP phase switch)
        let mut p0 = params.clone();
        for _ in 0..3 {
            st0.step(&mut p0, |_| (0.0, grads.clone()));
        }
        if switch {
            st0.switch_to_phase2();
            st0.step(&mut p0, |_| (0.0, grads.clone()));
        }

        // in-suite bit-equality gate: one lock-step step through both
        // pipelines from the settled state (the long 50-step equality lives
        // in rust/tests/recipe_fused.rs; this keeps the JSON's
        // outputs_bit_equal flag honest for the exact configs timed here)
        let mut st_a = st0.clone();
        let mut p_a = p0.clone();
        let mut st_b = st0.clone();
        let mut p_b = p0.clone();
        let (_, stats_a) = st_a.step(&mut p_a, |_| (0.0, grads.clone()));
        let (_, stats_b) = st_b.step_reference(&mut p_b, |_| (0.0, grads.clone()));
        assert_eq!(stats_a, stats_b, "{name}: fused/reference telemetry diverged");
        for i in 0..p_a.len() {
            assert_eq!(p_a[i], p_b[i], "{name}: fused/reference params diverged at {i}");
        }

        let mut st_fused = st0.clone();
        let mut p_fused = p0.clone();
        let r_fused = h.run(&format!("fused {name}"), || {
            st_fused.step(&mut p_fused, |_| (0.0, grads.clone()))
        });
        let mut st_ref = st0.clone();
        let mut p_ref = p0.clone();
        let r_ref = h.run(&format!("ref   {name}"), || {
            st_ref.step_reference(&mut p_ref, |_| (0.0, grads.clone()))
        });
        let cmp = Comparison {
            name: format!("{shape_name}/{name}"),
            baseline_mean: engine_mean(r_ref.mean()),
            fused_mean: engine_mean(r_fused.mean()),
        };
        println!("{}  ({:.1} Melem/s)", r_fused.row(), total as f64 / r_fused.mean() / 1e6);
        println!("{}  (fused speedup {:.2}x)", r_ref.row(), cmp.speedup());
        out.push(cmp);
    }
}

/// Packed-vs-dense inference throughput for one Table-1 MLP shape at 2:4.
///
/// The baseline is the dense *masked* forward — `Mlp::forward` over weights
/// with the learned mask already multiplied in (zeros cost full
/// multiply-adds and memory traffic). The packed side runs the same model
/// through the compressed-storage kernels. Outputs are asserted
/// bit-identical before anything is timed, so the comparison can never
/// silently measure two different computations.
fn bench_packed_inference(
    h: Harness,
    rng: &mut Pcg64,
    shape_name: &str,
    sizes: &[usize],
    out: &mut Vec<Comparison>,
) {
    print_header(&format!("packed inference — {shape_name} {sizes:?} @ 2:4"));
    let mlp = Mlp { sizes: sizes.to_vec() };
    let params = mlp.init(rng);
    let ratio = NmRatio::new(2, 4);
    let masked = mlp.masked_params(&params, ratio);
    let packed = mlp.pack_params(&params, ratio);
    let stored: usize = packed.iter().map(|p| p.stored_bytes()).sum();
    let dense_bytes: usize = packed.iter().map(|p| p.dense_bytes()).sum();
    println!(
        "packed weights: {:.2} MiB vs dense {:.2} MiB ({:.1}% of dense)",
        stored as f64 / (1 << 20) as f64,
        dense_bytes as f64 / (1 << 20) as f64,
        100.0 * stored as f64 / dense_bytes as f64
    );
    // correctness gate: bit-identical logits across kernel paths
    for &b in &[1usize, 8, 37] {
        let x = Tensor::randn(&[b, sizes[0]], rng, 0.0, 1.0);
        assert_eq!(
            mlp.forward(&masked, &x),
            mlp.forward_packed(&packed, &x),
            "packed forward diverged from dense masked forward at batch {b}"
        );
    }
    for &b in &[1usize, 8, 32] {
        let x = Tensor::randn(&[b, sizes[0]], rng, 0.0, 1.0);
        let r_dense = h.run(&format!("dense masked fwd  b={b}"), || mlp.forward(&masked, &x));
        let r_packed = h.run(&format!("packed fwd        b={b}"), || {
            mlp.forward_packed(&packed, &x)
        });
        let cmp = Comparison {
            name: format!("{shape_name}/fwd_b{b}"),
            baseline_mean: r_dense.mean(),
            fused_mean: r_packed.mean(),
        };
        println!("{}", r_dense.row());
        println!("{}  (packed speedup {:.2}x)", r_packed.row(), cmp.speedup());
        out.push(cmp);
    }
    // the serving path: pack once, serve repeated batches (threaded shards)
    let mut server = BatchServer::new(mlp.clone(), packed.clone()).expect("server");
    let xb = Tensor::randn(&[128, sizes[0]], rng, 0.0, 1.0);
    assert_eq!(
        mlp.forward(&masked, &xb),
        server.serve(&xb).expect("serve"),
        "serve path diverged"
    );
    let r_dense = h.run("dense masked fwd  b=128", || mlp.forward(&masked, &xb));
    let r_serve = h.run("packed serve      b=128", || server.serve(&xb).expect("serve"));
    let cmp = Comparison {
        name: format!("{shape_name}/serve_b128"),
        baseline_mean: r_dense.mean(),
        fused_mean: r_serve.mean(),
    };
    println!("{}", r_dense.row());
    println!("{}  (serve speedup {:.2}x)", r_serve.row(), cmp.speedup());
    out.push(cmp);
}

/// Packed fine-tune step vs dense-masked fine-tune step (frozen mask) for
/// one Table-1 MLP shape at 2:4 — `BENCH_finetune.json`.
///
/// The baseline is the frozen-mask regime trained the dense way: masked
/// weights, `Mlp::loss_and_grad` over all coordinates, gradients masked
/// back onto the support, and `numel`-sized Adam state. The packed side is
/// a [`FinetuneSession`]: compact gradients, `n_values()`-sized state, the
/// mask never re-applied because it cannot move. Before anything is timed
/// the two paths run lock-step steps and the loss bits plus every kept
/// coordinate are asserted equal — the comparison can never silently
/// measure two different computations.
fn bench_packed_finetune(
    h: Harness,
    rng: &mut Pcg64,
    shape_name: &str,
    sizes: &[usize],
    out: &mut Vec<Comparison>,
) {
    print_header(&format!("packed fine-tune — {shape_name} {sizes:?} @ 2:4"));
    let mlp = Mlp { sizes: sizes.to_vec() };
    let params = mlp.init(rng);
    let ratio = NmRatio::new(2, 4);
    let batch = 64usize;
    let n_classes = *sizes.last().expect("shape");
    let x = Tensor::randn(&[batch, sizes[0]], rng, 0.0, 1.0);
    let labels: Vec<usize> = (0..batch).map(|i| i % n_classes).collect();
    let lr = 1e-3f32;
    let hp = AdamHp::default();

    // packed side: compact gradients + compact Adam state
    let mut ft = FinetuneSession::pack(mlp.clone(), &params, ratio, lr, hp).expect("finetune");

    // dense-masked baseline state: masked weights + full-size Adam state.
    // The frozen mask is rebuilt from the packed *codes* (re-selecting via
    // nm_mask on already-masked weights could tie-break to a different
    // support on exact-zero kept values), so the gate can never diverge on
    // selection.
    let support_mask = |pk: &PackedNmTensor| -> Tensor {
        let mut mk = Tensor::zeros(pk.shape());
        let vpr = pk.values_per_row();
        let cols = pk.shape()[1];
        for (vc, &j) in pk.col_indices().iter().enumerate() {
            mk.data_mut()[(vc / vpr) * cols + j as usize] = 1.0;
        }
        mk
    };
    let masks: Vec<Option<Tensor>> = ft
        .params()
        .iter()
        .map(|p| p.as_packed().map(&support_mask))
        .collect();
    let mut dense_w = mlp.masked_params(&params, ratio);
    let mut dm: Vec<Tensor> = dense_w.iter().map(|p| Tensor::zeros(p.shape())).collect();
    let mut dv = dm.clone();
    let mut dt = 0u64;
    let mut dense_step = |w: &mut [Tensor], m: &mut [Tensor], v: &mut [Tensor], t: u64| -> f64 {
        let (loss, mut grads) = mlp.loss_and_grad(w, &x, &labels);
        for (g, mk) in grads.iter_mut().zip(&masks) {
            if let Some(mk) = mk {
                // frozen mask: gradients projected onto the kept support
                for (gd, &kd) in g.data_mut().iter_mut().zip(mk.data()) {
                    *gd *= kd;
                }
            }
        }
        for i in 0..w.len() {
            adam_update(&mut w[i], &mut m[i], &mut v[i], &grads[i], t, lr, hp);
        }
        loss
    };

    println!(
        "optimizer state: {} packed scalars vs {} dense ({:.1}%)",
        ft.optimizer_values(),
        ft.dense_optimizer_values(),
        100.0 * ft.optimizer_compression()
    );

    // correctness gate: lock-step bit-equality of loss and kept coordinates
    for k in 0..3 {
        dt += 1;
        let dl = dense_step(&mut dense_w, &mut dm, &mut dv, dt);
        let pl = ft.step(&x, &labels);
        assert_eq!(dl.to_bits(), pl.to_bits(), "fine-tune loss diverged at step {k}");
    }
    for (i, p) in ft.params().iter().enumerate() {
        match p.as_packed() {
            Some(pk) => assert_eq!(pk.unpack(), dense_w[i], "kept coords diverged, param {i}"),
            None => assert_eq!(*p.as_dense().expect("dense"), dense_w[i], "param {i} diverged"),
        }
    }

    let r_dense = h.run("dense masked ft step  b=64", || {
        dt += 1;
        dense_step(&mut dense_w, &mut dm, &mut dv, dt)
    });
    let r_packed = h.run("packed ft step        b=64", || ft.step(&x, &labels));
    let cmp = Comparison {
        name: format!("{shape_name}/finetune_b64"),
        baseline_mean: r_dense.mean(),
        fused_mean: r_packed.mean(),
    };
    println!("{}", r_dense.row());
    println!("{}  (packed speedup {:.2}x)", r_packed.row(), cmp.speedup());
    out.push(cmp);
}

/// Dense-vs-packed encoder forward on attention shapes — `BENCH_attention.json`.
///
/// The baseline is the dense *masked* forward of the pure-Rust
/// [`TokenEncoder`] (fused-QKV / output / FFN projections carry the learned
/// 2:4 mask as explicit zeros); the packed side runs the same encoder with
/// those four projections per block in compressed N:M storage. Logits are
/// asserted **bit-identical** across batch sizes before anything is timed,
/// and the serving row goes through the threaded [`BatchServer`] shards —
/// so the comparison can never silently measure two different computations.
fn bench_attention(h: Harness, rng: &mut Pcg64, out: &mut Vec<Comparison>) {
    // BERT-analog block geometry scaled to bench time: d=64, 4 heads,
    // ffn 256, 2 blocks, seq 32 — every sparse tensor is attention-shaped
    let enc = TokenEncoder::classifier(256, 64, 4, 256, 2, 32, 8);
    print_header(&format!(
        "packed attention — encoder d={} heads={} ffn={} blocks={} seq={} @ 2:4",
        enc.d_model, enc.n_heads, enc.d_ff, enc.n_blocks, enc.max_seq
    ));
    let params = enc.init(rng);
    let ratio = NmRatio::new(2, 4);
    let masked = enc.masked_params(&params, ratio);
    let packed = enc.pack_params(&params, ratio);
    let stored: usize = packed.iter().map(|p| p.stored_bytes()).sum();
    let dense_bytes: usize = packed.iter().map(|p| p.dense_bytes()).sum();
    println!(
        "packed weights: {:.2} MiB vs dense {:.2} MiB ({:.1}% of dense; embeddings/head stay dense)",
        stored as f64 / (1 << 20) as f64,
        dense_bytes as f64 / (1 << 20) as f64,
        100.0 * stored as f64 / dense_bytes as f64
    );
    let token_batch = |rng: &mut Pcg64, bsz: usize| -> Tensor {
        let ids: Vec<f32> = (0..bsz * enc.max_seq).map(|_| rng.below(enc.vocab) as f32).collect();
        Tensor::new(&[bsz, enc.max_seq], ids)
    };
    // correctness gate: bit-identical logits across kernel paths
    for &b in &[1usize, 8, 19] {
        let x = token_batch(rng, b);
        assert_eq!(
            enc.forward(&masked, &x),
            enc.forward_packed(&packed, &x),
            "packed encoder forward diverged from dense masked at batch {b}"
        );
    }
    for &b in &[1usize, 8, 32] {
        let x = token_batch(rng, b);
        let r_dense =
            h.run(&format!("dense masked enc fwd b={b}"), || enc.forward(&masked, &x));
        let r_packed = h.run(&format!("packed enc fwd       b={b}"), || {
            enc.forward_packed(&packed, &x)
        });
        let cmp = Comparison {
            name: format!("attention/fwd_b{b}"),
            baseline_mean: r_dense.mean(),
            fused_mean: r_packed.mean(),
        };
        println!("{}", r_dense.row());
        println!("{}  (packed speedup {:.2}x)", r_packed.row(), cmp.speedup());
        out.push(cmp);
    }
    // the serving path: pack once, serve repeated token batches
    let mut server = BatchServer::new(enc.clone(), packed.clone()).expect("server");
    let xb = token_batch(rng, 64);
    assert_eq!(
        enc.forward(&masked, &xb),
        server.serve(&xb).expect("serve"),
        "encoder serve path diverged"
    );
    let r_dense = h.run("dense masked enc fwd b=64", || enc.forward(&masked, &xb));
    let r_serve = h.run("packed enc serve     b=64", || server.serve(&xb).expect("serve"));
    let cmp = Comparison {
        name: "attention/serve_b64".into(),
        baseline_mean: r_dense.mean(),
        fused_mean: r_serve.mean(),
    };
    println!("{}", r_dense.row());
    println!("{}  (serve speedup {:.2}x)", r_serve.row(), cmp.speedup());
    out.push(cmp);
}

/// Feature matrix + class labels of a CIFAR-analog batch.
fn feat(b: &Batch) -> (&Tensor, &[usize]) {
    match (&b.x, &b.y) {
        (BatchX::Features(x), BatchY::Classes(y)) => (x, y),
        _ => panic!("CifarLike yields features/classes"),
    }
}

/// Streaming-driver overhead vs the manual batch-at-a-time loop —
/// `BENCH_train.json`.
///
/// Both sides consume the *same* seed-shuffled epoch stream; the baseline
/// calls `stream.train_batch(t, bs)` inline and steps the engine by hand,
/// the driver adds the full loop machinery (prefetch worker, cadences,
/// phase switching). Before anything is timed the two run several epochs in
/// lock step and every loss bit + the full parameter state are asserted
/// equal — then each side times whole epochs from that shared state. The
/// driver's prefetch overlap should keep its overhead ≤ 5% (speedup ≥
/// 0.95× — typically ≥ 1× since batch generation overlaps the step).
fn bench_train_driver(h: Harness, rng: &mut Pcg64, out: &mut Vec<Comparison>) {
    let (dim, classes) = (64usize, 10usize);
    let mlp = Mlp::new(dim, &[128], classes);
    let ds: std::sync::Arc<dyn Dataset> =
        std::sync::Arc::new(CifarLike::new(classes, dim, 0.8, 128, 7));
    let stream = MiniBatchStream::new(ds, 256, 32, 7).expect("stream");
    let bpe = stream.batches_per_epoch();
    print_header(&format!(
        "streaming train driver — mlp [{dim}, 128, {classes}], {} ex/epoch, bs {}",
        stream.n_examples(),
        stream.batch_size()
    ));

    // ---- dense recipe mode (STEP through the phase switch) ---------------
    let params0 = mlp.init(rng);
    let recipe0 = RecipeState::new(
        PureRecipe::Step { lam: 2e-4 },
        &params0,
        mlp.ratios(NmRatio::new(2, 4)),
        1e-3,
        AdamHp::default(),
    );
    let switch_at = bpe + 2; // mid second epoch
    let mut driver = TrainDriver::new_dense(
        mlp.clone(),
        params0.clone(),
        recipe0.clone(),
        stream.clone(),
        DriverConfig {
            epochs: usize::MAX / bpe, // never completes inside the bench
            switch_at: Some(switch_at),
            ..DriverConfig::default()
        },
    )
    .expect("driver");
    let mut st = recipe0;
    let mut p = params0;
    let mut t = 0usize;
    // bit-equality gate: two lock-step epochs before any timing
    for _ in 0..2 * bpe {
        t += 1;
        if t == switch_at {
            st.switch_to_phase2();
        }
        let b = stream.train_batch(t, stream.batch_size());
        let (x, y) = feat(&b);
        let (manual_loss, _) = st.step(&mut p, |mp| mlp.loss_and_grad(mp, x, y));
        let driver_loss = driver.step_once().expect("step").expect("not done");
        assert_eq!(
            driver_loss.to_bits(),
            manual_loss.to_bits(),
            "driver loss diverged from the manual loop at step {t}"
        );
    }
    assert_eq!(driver.dense_params().expect("dense"), &p[..], "driver params diverged");
    let r_manual = h.run("manual dense epoch ", || {
        for _ in 0..bpe {
            t += 1;
            let b = stream.train_batch(t, stream.batch_size());
            let (x, y) = feat(&b);
            st.step(&mut p, |mp| mlp.loss_and_grad(mp, x, y));
        }
    });
    let r_driver = h.run("driver dense epoch ", || {
        for _ in 0..bpe {
            driver.step_once().expect("step").expect("not done");
        }
    });
    let cmp = Comparison {
        name: "train/dense_epoch".into(),
        baseline_mean: r_manual.mean(),
        fused_mean: r_driver.mean(),
    };
    println!("{}", r_manual.row());
    println!(
        "{}  (driver speedup {:.2}x, overhead {:+.1}%)",
        r_driver.row(),
        cmp.speedup(),
        100.0 * (cmp.fused_mean / cmp.baseline_mean - 1.0)
    );
    out.push(cmp);

    // ---- packed fine-tune mode -------------------------------------------
    let params = mlp.init(rng);
    let ratio = NmRatio::new(2, 4);
    let hp = AdamHp::default();
    let ft0 = FinetuneSession::pack(mlp.clone(), &params, ratio, 1e-3, hp).expect("pack");
    let mut driver = TrainDriver::new_finetune(
        ft0,
        stream.clone(),
        DriverConfig { epochs: usize::MAX / bpe, ..DriverConfig::default() },
    )
    .expect("driver");
    let mut ft = FinetuneSession::pack(mlp.clone(), &params, ratio, 1e-3, hp).expect("pack");
    let mut t = 0usize;
    for _ in 0..2 * bpe {
        t += 1;
        let b = stream.train_batch(t, stream.batch_size());
        let (x, y) = feat(&b);
        let manual_loss = ft.step(x, y);
        let driver_loss = driver.step_once().expect("step").expect("not done");
        assert_eq!(
            driver_loss.to_bits(),
            manual_loss.to_bits(),
            "fine-tune driver loss diverged at step {t}"
        );
    }
    // loss equality pins the state only up to the step before; compare the
    // packed parameters themselves so the final update is gated too
    let dp = driver.session().expect("finetune mode").params();
    for (i, (a, b)) in dp.iter().zip(ft.params()).enumerate() {
        match (a, b) {
            (PackedParam::Packed(x), PackedParam::Packed(y)) => {
                assert_eq!(x, y, "fine-tune driver packed param {i} diverged")
            }
            (PackedParam::Dense(x), PackedParam::Dense(y)) => {
                assert_eq!(x, y, "fine-tune driver dense param {i} diverged")
            }
            other => panic!("fine-tune param {i}: storage kind mismatch {other:?}"),
        }
    }
    let r_manual = h.run("manual finetune epoch", || {
        for _ in 0..bpe {
            t += 1;
            let b = stream.train_batch(t, stream.batch_size());
            let (x, y) = feat(&b);
            ft.step(x, y);
        }
    });
    let r_driver = h.run("driver finetune epoch", || {
        for _ in 0..bpe {
            driver.step_once().expect("step").expect("not done");
        }
    });
    let cmp = Comparison {
        name: "train/finetune_epoch".into(),
        baseline_mean: r_manual.mean(),
        fused_mean: r_driver.mean(),
    };
    println!("{}", r_manual.row());
    println!(
        "{}  (driver speedup {:.2}x, overhead {:+.1}%)",
        r_driver.row(),
        cmp.speedup(),
        100.0 * (cmp.fused_mean / cmp.baseline_mean - 1.0)
    );
    out.push(cmp);
}

/// One closed-loop traffic round through the dynamic-batching frontend:
/// seeded clients with Poisson-like think times submit their scripts
/// concurrently; every response is asserted bit-equal to the solo
/// `BatchServer::serve` oracle **in the loop** (the `outputs_bit_equal`
/// gate), then the round is recorded as solo-sequential vs frontend
/// completion time for the same request set.
fn serving_round<M: SparseModel + 'static>(
    name: &str,
    mut solo: BatchServer<M>,
    frontend_server: BatchServer<M>,
    scripts: Vec<Vec<Tensor>>,
    cfg: FrontendConfig,
    think_mean_us: f64,
    out: &mut Vec<Comparison>,
) -> (FrontendStats, LatencyRecord, f64) {
    use std::time::{Duration, Instant};
    let n_req: usize = scripts.iter().map(Vec::len).sum();

    // solo baseline: strictly sequential, one request per serve call —
    // also precomputes the oracle responses the gate checks against
    let t0 = Instant::now();
    let oracle: Vec<Vec<Tensor>> = scripts
        .iter()
        .map(|s| s.iter().map(|x| solo.serve(x).unwrap()).collect())
        .collect();
    let solo_secs = t0.elapsed().as_secs_f64();

    let fe = std::sync::Arc::new(ServeFrontend::new(frontend_server, cfg).unwrap());
    let t0 = Instant::now();
    let mut clients = Vec::new();
    for (c, (script, want)) in scripts.into_iter().zip(oracle).enumerate() {
        let fe = std::sync::Arc::clone(&fe);
        let mut crng = Pcg64::new(7_000 + c as u64);
        // nm-lint: allow(thread-discipline): closed-loop traffic clients; every response is bit-gated against the solo oracle in-loop, so client scheduling cannot affect outputs
        clients.push(std::thread::spawn(move || {
            for (x, w) in script.iter().zip(&want) {
                if think_mean_us > 0.0 {
                    // Poisson-like arrivals: exponential think time
                    let dt = -think_mean_us * (1.0 - crng.f64()).ln();
                    std::thread::sleep(Duration::from_micros(dt as u64));
                }
                let handle = loop {
                    match fe.submit(x) {
                        Ok(h) => break h,
                        Err(SubmitError::QueueFull { .. }) => {
                            std::thread::sleep(Duration::from_micros(50));
                        }
                        Err(e) => panic!("serving submit failed: {e}"),
                    }
                };
                let got = handle.wait_timeout(Duration::from_secs(120)).unwrap();
                assert_eq!(&got, w, "frontend response != solo serve oracle");
            }
        }));
    }
    for c in clients {
        c.join().unwrap();
    }
    let mut fe = match std::sync::Arc::try_unwrap(fe) {
        Ok(fe) => fe,
        Err(_) => unreachable!("all clients joined"),
    };
    let stats = fe.shutdown(); // joins the workers: the record is final
    let latency = fe.latency_record();
    let fe_secs = t0.elapsed().as_secs_f64();

    let cmp = Comparison {
        name: name.to_string(),
        baseline_mean: solo_secs / n_req.max(1) as f64,
        fused_mean: fe_secs / n_req.max(1) as f64,
    };
    println!(
        "{name:<44} solo {:>10}  frontend {:>10}  p50 {:>10}  p99 {:>10}  {:.1} rows/batch",
        step_nm::bench::fmt_time(cmp.baseline_mean),
        step_nm::bench::fmt_time(cmp.fused_mean),
        step_nm::bench::fmt_time(stats.latency.p50_ns as f64 * 1e-9),
        step_nm::bench::fmt_time(stats.latency.p99_ns as f64 * 1e-9),
        stats.mean_batch_rows(),
    );
    out.push(cmp);
    (stats, latency, fe_secs)
}

/// The online-serving suite: closed-loop seeded traffic (mixed request
/// sizes, Mlp + TokenEncoder, ragged token sequences) through the
/// dynamic-batching frontend, recorded to `BENCH_serving.json` with
/// exact-order latency percentiles and throughput extras.
fn bench_serving(
    smoke: bool,
    rng: &mut Pcg64,
    out: &mut Vec<Comparison>,
) -> step_nm::util::json::JsonObj {
    use step_nm::util::json::{Json, JsonObj};
    print_header("online serving: dynamic-batching frontend vs solo sequential serve");
    let clients = if smoke { 2usize } else { 4 };
    let reqs = if smoke { 3usize } else { 40 };
    let think_mean_us = if smoke { 0.0 } else { 150.0 };
    let cfg = FrontendConfig {
        max_batch_rows: 16,
        max_wait: std::time::Duration::from_micros(500),
        queue_cap: 256,
        workers: 2,
    };

    let mut agg = LatencyRecord::new();
    let mut total_requests = 0usize;
    let mut total_rows = 0usize;
    let mut total_batches = 0usize;
    let mut total_secs = 0.0f64;
    let mut track = |res: (FrontendStats, LatencyRecord, f64)| {
        let (stats, latency, secs) = res;
        for &ns in latency.samples_ns() {
            agg.push(ns);
        }
        total_requests += stats.serve.requests;
        total_rows += stats.serve.samples;
        total_batches += stats.serve.batches;
        total_secs += secs;
    };

    // MLP feature batches at 2:4 and 1:4, mixed 1..=6-row requests
    for ratio in [NmRatio::new(2, 4), NmRatio::new(1, 4)] {
        let mlp = Mlp::new(64, &[128, 64], 10);
        let params = mlp.init(rng);
        let scripts: Vec<Vec<Tensor>> = (0..clients)
            .map(|_| {
                (0..reqs)
                    .map(|_| {
                        let rows = 1 + rng.below(6);
                        Tensor::randn(&[rows, 64], rng, 0.0, 1.0)
                    })
                    .collect()
            })
            .collect();
        let solo = BatchServer::pack(mlp.clone(), &params, ratio).unwrap();
        let fe = BatchServer::pack(mlp, &params, ratio).unwrap();
        track(serving_round(
            &format!("serving mlp {}:{} {clients}x{reqs} reqs", ratio.n, ratio.m),
            solo,
            fe,
            scripts,
            cfg,
            think_mean_us,
            out,
        ));
    }

    // token-encoder batches (2:4), ragged sequence lengths — different
    // seqs never share a batch, so the dim-grouped cut rule is exercised
    let enc = TokenEncoder::classifier(32, 16, 2, 32, 1, 8, 4);
    let params = SparseModel::init(&enc, rng);
    let ratio = NmRatio::new(2, 4);
    let scripts: Vec<Vec<Tensor>> = (0..clients)
        .map(|_| {
            (0..reqs)
                .map(|_| {
                    let rows = 1 + rng.below(4);
                    let seq = [4usize, 6, 8][rng.below(3)];
                    let ids: Vec<f32> =
                        (0..rows * seq).map(|_| rng.below(32) as f32).collect();
                    Tensor::new(&[rows, seq], ids)
                })
                .collect()
        })
        .collect();
    let solo = BatchServer::pack(enc.clone(), &params, ratio).unwrap();
    let fe = BatchServer::pack(enc, &params, ratio).unwrap();
    track(serving_round(
        &format!("serving encoder 2:4 ragged {clients}x{reqs} reqs"),
        solo,
        fe,
        scripts,
        cfg,
        think_mean_us,
        out,
    ));

    // exact-order percentile extras: deterministic given the recorded
    // latency sequence (the pinned rule in coordinator::frontend::stats)
    let mut extras = JsonObj::new();
    extras.insert("requests", Json::Num(total_requests as f64));
    extras.insert("p50_latency_ns", Json::Num(agg.p50_ns() as f64));
    extras.insert("p95_latency_ns", Json::Num(agg.p95_ns() as f64));
    extras.insert("p99_latency_ns", Json::Num(agg.p99_ns() as f64));
    extras.insert("max_latency_ns", Json::Num(agg.max_ns() as f64));
    extras.insert("mean_latency_ns", Json::Num(agg.mean_ns() as f64));
    extras.insert(
        "requests_per_sec",
        Json::Num(total_requests as f64 / total_secs.max(1e-12)),
    );
    extras.insert("rows_per_sec", Json::Num(total_rows as f64 / total_secs.max(1e-12)));
    extras.insert(
        "mean_batch_rows",
        Json::Num(total_rows as f64 / total_batches.max(1) as f64),
    );
    extras
}

/// The autoregressive-generation suite: batched greedy decoding through
/// the packed KV-cache path ([`BatchGenerator`]) vs the dense masked
/// full-recompute oracle — `BENCH_generation.json`.
///
/// Two in-suite gates run before any timing:
/// 1. **Per-step bit-identity.** Over a teacher-forced full-length prefix,
///    every `decode_step_packed` logits row is asserted bit-equal to the
///    dense `decode_step` AND to the dense masked full forward recomputed
///    from scratch over the whole prefix — the KV cache must be invisible
///    at the bit level.
/// 2. **Whole-trajectory identity.** `BatchGenerator::generate` over a
///    ragged batch (with an eot stop, so cache eviction fires mid-run) is
///    asserted token-for-token equal to a per-sequence greedy loop that
///    recomputes the dense masked full forward at every step.
fn bench_generation(
    h: Harness,
    smoke: bool,
    rng: &mut Pcg64,
    out: &mut Vec<Comparison>,
) -> step_nm::util::json::JsonObj {
    use step_nm::util::json::{Json, JsonObj};
    print_header("autoregressive generation: packed KV-cache decode vs dense full recompute");
    let max_seq = if smoke { 12 } else { 24 };
    let dec = TokenDecoder::new(32, 16, 2, 32, 2, max_seq);
    let params = dec.init(rng);

    // the dense greedy full-recompute oracle for one sequence
    let oracle_one = |masked: &[Tensor], prompt: &[usize], cfg: &GenerateConfig| {
        let mut seq = prompt.to_vec();
        let mut generated = 0usize;
        while generated < cfg.max_new_tokens && seq.len() < dec.max_seq {
            let ids: Vec<f32> = seq.iter().map(|&i| i as f32).collect();
            let logits = dec.forward(masked, &Tensor::new(&[1, seq.len()], ids));
            let tok = argmax_rows(&logits)[0];
            seq.push(tok);
            generated += 1;
            if Some(tok) == cfg.eot {
                break;
            }
        }
        seq
    };

    let mut generated_tokens = 0usize;
    let mut decode_steps = 0usize;
    let mut packed_secs = 0.0f64;
    for ratio in [NmRatio::new(2, 4), NmRatio::new(1, 4)] {
        let packed = dec.pack_params(&params, ratio);
        let masked: Vec<Tensor> = packed.iter().map(|p| p.unpack()).collect();

        // gate 1: per-step bit-identity over a teacher-forced full prefix
        let bsz = 2usize;
        let seqs: Vec<Vec<usize>> = (0..bsz)
            .map(|_| (0..dec.max_seq).map(|_| rng.below(32)).collect())
            .collect();
        let mut kv_packed = dec.new_cache(bsz);
        let mut kv_dense = dec.new_cache(bsz);
        for t in 0..dec.max_seq {
            let ids: Vec<usize> = seqs.iter().map(|s| s[t]).collect();
            let lp = dec.decode_step_packed(&packed, &mut kv_packed, &ids).unwrap();
            let ld = dec.decode_step(&masked, &mut kv_dense, &ids).unwrap();
            let prefix: Vec<f32> = seqs
                .iter()
                .flat_map(|s| s[..=t].iter().map(|&i| i as f32))
                .collect();
            let full = dec.forward(&masked, &Tensor::new(&[bsz, t + 1], prefix));
            assert_eq!(
                lp.data(),
                full.data(),
                "packed KV decode != dense full recompute at step {t} ({}:{})",
                ratio.n,
                ratio.m
            );
            assert_eq!(
                ld.data(),
                full.data(),
                "dense KV decode != dense full recompute at step {t} ({}:{})",
                ratio.n,
                ratio.m
            );
        }

        // gate 2: whole-trajectory identity, ragged prompts + eviction
        let gen = BatchGenerator::new(dec.clone(), packed).unwrap();
        let prompts: Vec<Vec<usize>> = (0..4)
            .map(|i| (0..=i).map(|_| rng.below(32)).collect())
            .collect();
        let eot_cfg = GenerateConfig { max_new_tokens: dec.max_seq, eot: Some(0) };
        let got = gen.generate(&prompts, &eot_cfg).unwrap();
        for (r, p) in prompts.iter().enumerate() {
            let want = oracle_one(&masked, p, &eot_cfg);
            assert_eq!(
                got.tokens[r], want,
                "generated tokens diverge from the dense oracle (seq {r}, {}:{})",
                ratio.n, ratio.m
            );
        }

        // timing: the same ragged batch, full-length budget, no eot — the
        // baseline regenerates every sequence by dense full recompute
        let cfg = GenerateConfig { max_new_tokens: dec.max_seq, eot: None };
        let r_dense = h.run(&format!("dense recompute generate {}:{}", ratio.n, ratio.m), || {
            prompts
                .iter()
                .map(|p| oracle_one(&masked, p, &cfg).len())
                .sum::<usize>()
        });
        let r_packed = h.run(&format!("packed kv-cache generate {}:{}", ratio.n, ratio.m), || {
            gen.generate(&prompts, &cfg).unwrap().new_tokens
        });
        let timed = gen.generate(&prompts, &cfg).unwrap();
        generated_tokens += timed.new_tokens;
        decode_steps += timed.steps;
        packed_secs += r_packed.mean();
        let cmp = Comparison {
            name: format!("generation {}:{} kv-cache vs recompute", ratio.n, ratio.m),
            baseline_mean: r_dense.mean(),
            fused_mean: r_packed.mean(),
        };
        println!("{}", r_dense.row());
        println!("{}  (kv-cache speedup {:.2}x)", r_packed.row(), cmp.speedup());
        out.push(cmp);
    }

    let mut extras = JsonObj::new();
    extras.insert("generated_tokens", Json::Num(generated_tokens as f64));
    extras.insert("decode_steps", Json::Num(decode_steps as f64));
    extras.insert(
        "tokens_per_sec",
        Json::Num(generated_tokens as f64 / packed_secs.max(1e-12)),
    );
    extras
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var_os("BENCH_SMOKE").is_some();
    let h = if smoke {
        println!("[smoke] reduced-iteration mode: timings are not meaningful");
        Harness {
            warmup: 0,
            min_iters: 1,
            max_iters: 2,
            min_time: std::time::Duration::ZERO,
        }
    } else {
        Harness::default()
    };
    let suite_h = if smoke {
        h
    } else {
        Harness {
            warmup: 2,
            min_iters: 5,
            max_iters: 200,
            min_time: std::time::Duration::from_millis(150),
        }
    };
    let mut rng = Pcg64::new(42);

    print_header("N:M mask selection (512x512 f32)");
    let w = Tensor::randn(&[512, 512], &mut rng, 0.0, 1.0);
    let mut mask = Tensor::zeros(&[512, 512]);
    for (n, m) in [(2usize, 4usize), (1, 4), (2, 8), (4, 16), (8, 32)] {
        let r = h.run(&format!("nm_mask {n}:{m}"), || {
            nm_mask_into(&w, NmRatio::new(n, m), &mut mask)
        });
        println!("{}  ({:.1} Melem/s)", r.row(), 512.0 * 512.0 / r.mean() / 1e6);
    }
    let mut wc = w.clone();
    let r = h.run("apply_nm_inplace 2:4", || {
        wc.data_mut().copy_from_slice(w.data());
        apply_nm_inplace(&mut wc, NmRatio::new(2, 4))
    });
    println!("{}  ({:.1} Melem/s)", r.row(), 512.0 * 512.0 / r.mean() / 1e6);

    print_header("blocked matmuls (training shapes)");
    let x = Tensor::randn(&[128, 768], &mut rng, 0.0, 1.0);
    let w1 = Tensor::randn(&[768, 512], &mut rng, 0.0, 1.0);
    let dy = Tensor::randn(&[128, 512], &mut rng, 0.0, 1.0);
    let flops = 2.0 * 128.0 * 768.0 * 512.0;
    let r = h.run("fwd   x@w    128x768x512", || matmul(&x, &w1));
    println!("{}  ({:.2} GFLOP/s)", r.row(), flops / r.mean() / 1e9);
    let r = h.run("bwd-x dy@wT  128x512x768", || matmul_bt(&dy, &w1));
    println!("{}  ({:.2} GFLOP/s)", r.row(), flops / r.mean() / 1e9);
    let r = h.run("bwd-w xT@dy  768x128x512", || matmul_at(&x, &dy));
    println!("{}  ({:.2} GFLOP/s)", r.row(), flops / r.mean() / 1e9);

    print_header("fused optimizer updates (512x512)");
    let g = Tensor::randn(&[512, 512], &mut rng, 0.0, 0.1);
    let mut p = w.clone();
    let mut m = Tensor::zeros(&[512, 512]);
    let mut v = Tensor::zeros(&[512, 512]);
    let r = h.run("adam_update", || {
        adam_update(&mut p, &mut m, &mut v, &g, 100, 1e-3, AdamHp::default())
    });
    println!("{}  ({:.1} Melem/s)", r.row(), 512.0 * 512.0 / r.mean() / 1e6);
    let v_star = Tensor::full(&[512, 512], 0.01);
    let r = h.run("step_phase2_update", || {
        step_phase2_update(&mut p, &mut m, &v_star, &g, 100, 1e-3, 0.9, 1e-8)
    });
    println!("{}  ({:.1} Melem/s)", r.row(), 512.0 * 512.0 / r.mean() / 1e6);
    let mut buf = Tensor::zeros(&[512, 512]);
    let r = h.run("sgdm_update", || {
        sgdm_update(&mut p, &mut buf, &g, 1e-2, 0.9)
    });
    println!("{}  ({:.1} Melem/s)", r.row(), 512.0 * 512.0 / r.mean() / 1e6);

    print_header("AutoSwitch observe() (per-step cost)");
    let mut asw = AutoSwitch::new(1_000_000, 1e-8, 0.999, ZOption::Arithmetic);
    let stat = SwitchStat { v_l1: 1.0, v_l2: 1.0, dv_l1: 0.5, log_dv: -10.0 };
    let mut t = 0usize;
    let r = h.run("autoswitch observe", || {
        t += 1;
        asw.observe(t, stat)
    });
    println!("{}", r.row());

    // ---- recipe-engine step throughput (Table-1 workload shapes) --------
    let mut comparisons = Vec::new();
    bench_recipe_steps(suite_h, &mut rng, "mlp_cf10", &[3072, 512, 512, 10], &mut comparisons);
    bench_recipe_steps(suite_h, &mut rng, "enc_glue2_ffn", &[512, 2048, 512, 2], &mut comparisons);
    let mean = comparisons.iter().map(Comparison::speedup).sum::<f64>()
        / comparisons.len().max(1) as f64;
    println!("\nmean fused speedup over reference: {mean:.2}x");
    match write_comparison_json(
        "BENCH_recipes.json",
        "recipe step throughput (fused vs reference, Table-1 shapes; engine-only means, closure cost subtracted)",
        &comparisons,
        true, // in-suite lock-step gate above + recipe_fused.rs (50 steps)
    ) {
        Ok(()) => println!("[json] wrote BENCH_recipes.json"),
        Err(e) => eprintln!("[json] could not write BENCH_recipes.json: {e}"),
    }

    // ---- packed inference throughput (Table-1 shapes, 2:4) --------------
    let mut inference = Vec::new();
    bench_packed_inference(suite_h, &mut rng, "mlp_cf10", &[3072, 512, 512, 10], &mut inference);
    bench_packed_inference(suite_h, &mut rng, "enc_glue2_ffn", &[512, 2048, 512, 2], &mut inference);
    let mean = inference.iter().map(Comparison::speedup).sum::<f64>()
        / inference.len().max(1) as f64;
    println!("\nmean packed speedup over dense masked forward: {mean:.2}x");
    match write_comparison_json(
        "BENCH_inference.json",
        "packed N:M forward vs dense masked forward (2:4, Table-1 shapes; packed = compressed storage + sparse kernels, serve row = threaded batch serving)",
        &inference,
        true, // logits asserted bit-identical in-suite before timing
    ) {
        Ok(()) => println!("[json] wrote BENCH_inference.json"),
        Err(e) => eprintln!("[json] could not write BENCH_inference.json: {e}"),
    }

    // ---- packed fine-tune step throughput (Table-1 shapes, 2:4) ---------
    let mut finetune = Vec::new();
    bench_packed_finetune(suite_h, &mut rng, "mlp_cf10", &[3072, 512, 512, 10], &mut finetune);
    bench_packed_finetune(suite_h, &mut rng, "enc_glue2_ffn", &[512, 2048, 512, 2], &mut finetune);
    let mean = finetune.iter().map(Comparison::speedup).sum::<f64>()
        / finetune.len().max(1) as f64;
    println!("\nmean packed fine-tune speedup over dense masked step: {mean:.2}x");
    match write_comparison_json(
        "BENCH_finetune.json",
        "packed fine-tune step vs dense masked step (2:4, Table-1 shapes; frozen mask — compact grads + n_values Adam state vs masked grads + numel state; loss bits and kept coordinates asserted equal before timing)",
        &finetune,
        true, // lock-step bit-equality gate in-suite before timing
    ) {
        Ok(()) => println!("[json] wrote BENCH_finetune.json"),
        Err(e) => eprintln!("[json] could not write BENCH_finetune.json: {e}"),
    }

    // ---- packed attention forward (encoder shapes, 2:4) ------------------
    let mut attention = Vec::new();
    bench_attention(suite_h, &mut rng, &mut attention);
    let mean = attention.iter().map(Comparison::speedup).sum::<f64>()
        / attention.len().max(1) as f64;
    println!("\nmean packed speedup over dense masked encoder forward: {mean:.2}x");
    match write_comparison_json(
        "BENCH_attention.json",
        "packed N:M encoder forward vs dense masked forward (2:4, fused-QKV/out/FFN projections packed, embeddings/head dense; logits asserted bit-identical in-suite before timing; serve row = threaded batch serving)",
        &attention,
        true, // logits asserted bit-identical in-suite before timing
    ) {
        Ok(()) => println!("[json] wrote BENCH_attention.json"),
        Err(e) => eprintln!("[json] could not write BENCH_attention.json: {e}"),
    }

    // ---- streaming driver vs manual batch-at-a-time loop -----------------
    let mut train = Vec::new();
    bench_train_driver(suite_h, &mut rng, &mut train);
    let mean = train.iter().map(Comparison::speedup).sum::<f64>()
        / train.len().max(1) as f64;
    println!(
        "\nmean driver speedup over the manual loop: {mean:.2}x (>= 0.95x keeps overhead within the 5% budget)"
    );
    match write_comparison_json(
        "BENCH_train.json",
        "streaming TrainDriver epoch vs manual batch-at-a-time loop (dense STEP recipe + packed fine-tune over a seed-shuffled MiniBatchStream; losses and parameter state asserted bit-equal in lock step before timing; speedup >= 0.95 means driver overhead <= 5%)",
        &train,
        true, // two lock-step epochs gated in-suite before timing
    ) {
        Ok(()) => println!("[json] wrote BENCH_train.json"),
        Err(e) => eprintln!("[json] could not write BENCH_train.json: {e}"),
    }

    // ---- online serving: frontend vs solo sequential serving -------------
    let mut serving = Vec::new();
    let extras = bench_serving(smoke, &mut rng, &mut serving);
    let mean = serving.iter().map(Comparison::speedup).sum::<f64>()
        / serving.len().max(1) as f64;
    println!(
        "\nmean closed-loop serving speedup over solo sequential serve: {mean:.2}x \
         (rows compare completion time for the same seeded traffic; the frontend \
         side includes client think times, so latency extras are the headline)"
    );
    match write_comparison_json_with(
        "BENCH_serving.json",
        "dynamic-batching frontend vs solo sequential BatchServer::serve (closed-loop seeded clients, Poisson-like think times, mixed request sizes, Mlp 2:4/1:4 + ragged TokenEncoder 2:4; every response asserted bit-identical to the solo oracle in-loop before recording; extras carry exact-order latency percentiles + throughput)",
        &serving,
        true, // per-response bit-equality gate inside serving_round
        &extras,
    ) {
        Ok(()) => println!("[json] wrote BENCH_serving.json"),
        Err(e) => eprintln!("[json] could not write BENCH_serving.json: {e}"),
    }

    // ---- autoregressive generation: packed KV cache vs full recompute ----
    let mut generation = Vec::new();
    let extras = bench_generation(suite_h, smoke, &mut rng, &mut generation);
    let mean = generation.iter().map(Comparison::speedup).sum::<f64>()
        / generation.len().max(1) as f64;
    println!(
        "\nmean kv-cache generation speedup over dense full recompute: {mean:.2}x \
         (every step's logits and every greedy trajectory gated bit-identical \
         to the dense masked oracle before timing)"
    );
    match write_comparison_json_with(
        "BENCH_generation.json",
        "KV-cached packed greedy generation (BatchGenerator over TokenDecoder, lock-step batch with eviction) vs dense masked full-recompute greedy loop (2:4 and 1:4; per-step logits and whole trajectories asserted bit-identical to the dense oracle in-suite before timing; extras carry token throughput)",
        &generation,
        true, // per-step + per-trajectory bit gates inside bench_generation
        &extras,
    ) {
        Ok(()) => println!("[json] wrote BENCH_generation.json"),
        Err(e) => eprintln!("[json] could not write BENCH_generation.json: {e}"),
    }
}
