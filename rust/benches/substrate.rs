//! `cargo bench --bench substrate` — pure-Rust hot-path kernels: N:M mask
//! selection, the blocked matmuls, fused optimizer updates, and the
//! AutoSwitch window. These are the L3 components on the per-step path.

use step_nm::autoswitch::{AutoSwitch, SwitchPolicy, SwitchStat, ZOption};
use step_nm::bench::{print_header, Harness};
use step_nm::optim::{adam_update, sgdm_update, step_phase2_update, AdamHp};
use step_nm::rng::Pcg64;
use step_nm::sparsity::{apply_nm_inplace, nm_mask_into, NmRatio};
use step_nm::tensor::{matmul, matmul_at, matmul_bt, Tensor};

fn main() {
    let h = Harness::default();
    let mut rng = Pcg64::new(42);

    print_header("N:M mask selection (512x512 f32)");
    let w = Tensor::randn(&[512, 512], &mut rng, 0.0, 1.0);
    let mut mask = Tensor::zeros(&[512, 512]);
    for (n, m) in [(2usize, 4usize), (1, 4), (2, 8), (4, 16), (8, 32)] {
        let r = h.run(&format!("nm_mask {n}:{m}"), || {
            nm_mask_into(&w, NmRatio::new(n, m), &mut mask)
        });
        println!("{}  ({:.1} Melem/s)", r.row(), 512.0 * 512.0 / r.mean() / 1e6);
    }
    let mut wc = w.clone();
    let r = h.run("apply_nm_inplace 2:4", || {
        wc.data_mut().copy_from_slice(w.data());
        apply_nm_inplace(&mut wc, NmRatio::new(2, 4))
    });
    println!("{}  ({:.1} Melem/s)", r.row(), 512.0 * 512.0 / r.mean() / 1e6);

    print_header("blocked matmuls (training shapes)");
    let x = Tensor::randn(&[128, 768], &mut rng, 0.0, 1.0);
    let w1 = Tensor::randn(&[768, 512], &mut rng, 0.0, 1.0);
    let dy = Tensor::randn(&[128, 512], &mut rng, 0.0, 1.0);
    let flops = 2.0 * 128.0 * 768.0 * 512.0;
    let r = h.run("fwd   x@w    128x768x512", || matmul(&x, &w1));
    println!("{}  ({:.2} GFLOP/s)", r.row(), flops / r.mean() / 1e9);
    let r = h.run("bwd-x dy@wT  128x512x768", || matmul_bt(&dy, &w1));
    println!("{}  ({:.2} GFLOP/s)", r.row(), flops / r.mean() / 1e9);
    let r = h.run("bwd-w xT@dy  768x128x512", || matmul_at(&x, &dy));
    println!("{}  ({:.2} GFLOP/s)", r.row(), flops / r.mean() / 1e9);

    print_header("fused optimizer updates (512x512)");
    let g = Tensor::randn(&[512, 512], &mut rng, 0.0, 0.1);
    let mut p = w.clone();
    let mut m = Tensor::zeros(&[512, 512]);
    let mut v = Tensor::zeros(&[512, 512]);
    let r = h.run("adam_update", || {
        adam_update(&mut p, &mut m, &mut v, &g, 100, 1e-3, AdamHp::default())
    });
    println!("{}  ({:.1} Melem/s)", r.row(), 512.0 * 512.0 / r.mean() / 1e6);
    let v_star = Tensor::full(&[512, 512], 0.01);
    let r = h.run("step_phase2_update", || {
        step_phase2_update(&mut p, &mut m, &v_star, &g, 100, 1e-3, 0.9, 1e-8)
    });
    println!("{}  ({:.1} Melem/s)", r.row(), 512.0 * 512.0 / r.mean() / 1e6);
    let mut buf = Tensor::zeros(&[512, 512]);
    let r = h.run("sgdm_update", || {
        sgdm_update(&mut p, &mut buf, &g, 1e-2, 0.9)
    });
    println!("{}  ({:.1} Melem/s)", r.row(), 512.0 * 512.0 / r.mean() / 1e6);

    print_header("AutoSwitch observe() (per-step cost)");
    let mut asw = AutoSwitch::new(1_000_000, 1e-8, 0.999, ZOption::Arithmetic);
    let stat = SwitchStat { v_l1: 1.0, v_l2: 1.0, dv_l1: 0.5, log_dv: -10.0 };
    let mut t = 0usize;
    let r = h.run("autoswitch observe", || {
        t += 1;
        asw.observe(t, stat)
    });
    println!("{}", r.row());
}
