//! `cargo bench --bench pjrt_step` — end-to-end per-step latency of every
//! recipe artifact through the PJRT runtime, plus the coordinator-overhead
//! accounting that EXPERIMENTS.md §Perf tracks. One row per paper recipe.

use step_nm::bench::{print_header, Harness};
use step_nm::config::{ExperimentConfig, RecipeKind};
use step_nm::coordinator::Session;
use step_nm::runtime::Runtime;

fn bench_model(rt: &Runtime, model: &str, recipes: &[(&str, RecipeKind, &str)]) {
    let h = Harness { warmup: 2, min_iters: 5, max_iters: 40,
        min_time: std::time::Duration::from_millis(400) };
    print_header(&format!("PJRT per-step latency — {model}"));
    for (label, recipe, ratio) in recipes {
        let mut cfg = ExperimentConfig::builder(model)
            .recipe(*recipe)
            .steps(10_000)
            .lr(1e-4)
            .build();
        cfg.ratio = ratio.parse().unwrap();
        cfg.autoswitch.fixed_step = Some(1);
        let mut session = match Session::new(rt, &cfg) {
            Ok(s) => s,
            Err(e) => {
                println!("  {label}: skipped ({e})");
                continue;
            }
        };
        // warm cache + cross the phase switch for STEP
        session.step().unwrap();
        session.step().unwrap();
        rt.reset_stats();
        let r = h.run(label, || session.step().unwrap());
        let st = rt.stats();
        let per_exec = st.execute_secs / st.executions.max(1) as f64;
        let overhead = (r.mean() - per_exec).max(0.0) / r.mean();
        println!(
            "{}  (XLA {:.1}ms/step, coordinator overhead {:.1}%)",
            r.row(),
            per_exec * 1e3,
            overhead * 100.0
        );
    }
}

fn main() {
    let rt = Runtime::from_dir("artifacts").expect("run `make artifacts` first");
    let full: Vec<(&str, RecipeKind, &str)> = vec![
        ("dense_adam", RecipeKind::Dense, "2:4"),
        ("dense_sgdm", RecipeKind::DenseSgdm, "2:4"),
        ("srste_adam 1:4", RecipeKind::SrSte, "1:4"),
        ("asp_adam 1:4", RecipeKind::Asp, "1:4"),
        ("step phase2 1:4", RecipeKind::Step, "1:4"),
        ("step phase2 1:16", RecipeKind::Step, "1:16"),
    ];
    bench_model(&rt, "mlp_cf10", &full);
    let lm: Vec<(&str, RecipeKind, &str)> = vec![
        ("dense_adam", RecipeKind::Dense, "2:4"),
        ("srste_adam 2:4", RecipeKind::SrSte, "2:4"),
        ("step phase2 2:4", RecipeKind::Step, "2:4"),
    ];
    bench_model(&rt, "lm_wiki", &lm);

    // eval-path latency
    print_header("eval latency (masked forward, 6 batches)");
    let h = Harness::quick();
    let cfg = ExperimentConfig::builder("mlp_cf10")
        .recipe(RecipeKind::SrSte)
        .sparsity(1, 4)
        .eval_batches(6)
        .lr(1e-4)
        .build();
    let session = Session::new(&rt, &cfg).unwrap();
    let r = h.run("eval mlp_cf10 1:4", || session.evaluate().unwrap());
    println!("{}", r.row());
}
