//! Offline stand-in for the `anyhow` crate, covering exactly the surface
//! step-nm uses: [`Error`], [`Result`], and the `anyhow!` / `bail!` /
//! `ensure!` macros (all call sites are fully path-qualified, e.g.
//! `anyhow::bail!`). The image this repo builds in has no crates.io access,
//! so the dependency is vendored as a path crate.
//!
//! Differences from the real crate (acceptable for this project):
//! * the error holds a rendered message, not the source chain — `{:#}`
//!   alternate formatting prints the same message;
//! * no `Context` extension trait (unused here);
//! * no backtrace capture.

use std::fmt;

/// A rendered, type-erased error.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { msg: message.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that is
// what keeps this blanket conversion coherent (mirroring the real anyhow).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        Self::msg(&e)
    }
}

/// `Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)+) => {
        $crate::Error::msg(format!($($arg)+))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an [`Error`] when the condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            $crate::bail!($($arg)+);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<u32> {
        let n: u32 = s.parse()?; // std error converts via the blanket From
        crate::ensure!(n < 100, "too big: {n}");
        if n == 13 {
            crate::bail!("unlucky {n}");
        }
        Ok(n)
    }

    #[test]
    fn conversions_and_macros() {
        assert_eq!(parse("7").unwrap(), 7);
        assert!(parse("x").is_err());
        assert_eq!(parse("200").unwrap_err().to_string(), "too big: 200");
        assert_eq!(parse("13").unwrap_err().to_string(), "unlucky 13");
        let e = crate::anyhow!("code {}", 42);
        assert_eq!(format!("{e}"), "code 42");
        assert_eq!(format!("{e:#}"), "code 42");
        assert_eq!(format!("{e:?}"), "code 42");
    }

    #[test]
    fn question_mark_through_anyhow_results() {
        fn outer() -> Result<()> {
            parse("13")?;
            Ok(())
        }
        assert!(outer().is_err());
    }
}
