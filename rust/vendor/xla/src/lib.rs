//! Offline stub of the `xla` PJRT bindings (the subset `step_nm::runtime`
//! consumes). The build image has no XLA toolchain, so this crate keeps the
//! runtime layer compiling and lets everything artifact-independent (the
//! pure-Rust engine, the manifest/value plumbing, all unit tests) run.
//!
//! Behavior:
//! * [`Literal`] is fully functional (typed byte storage + reinterpreting
//!   readback), so the `Value ↔ Literal` conversion tests pass;
//! * client/executable entry points that would need a real PJRT backend
//!   ([`PjRtClient::compile`], [`HloModuleProto::from_text_file`],
//!   [`PjRtLoadedExecutable::execute_b`]) return a descriptive [`Error`] —
//!   the coordinator surfaces it as "PJRT unavailable", and every
//!   artifact-dependent test already skips when `artifacts/` is absent.
//!
//! Swap this path dependency for the real bindings to execute HLO artifacts.

use std::path::Path;

/// Stub error type; formatted with `{:?}` by the runtime's `map_err` calls.
pub struct Error(pub String);

impl std::fmt::Debug for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what} is unavailable: step-nm was built against the offline xla stub \
         (rust/vendor/xla); link the real PJRT bindings to execute artifacts"
    ))
}

/// Element types the runtime moves across the boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// Host dtypes that can cross into a [`Literal`].
pub trait NativeType: Copy {
    const ELEMENT_TYPE: ElementType;
}

impl NativeType for f32 {
    const ELEMENT_TYPE: ElementType = ElementType::F32;
}

impl NativeType for i32 {
    const ELEMENT_TYPE: ElementType = ElementType::S32;
}

/// A typed host literal: shape + raw little-endian bytes. Functional in the
/// stub (the conversion layer is pure host code).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    ty: ElementType,
    shape: Vec<usize>,
    bytes: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        shape: &[usize],
        bytes: &[u8],
    ) -> Result<Self> {
        let numel: usize = shape.iter().product();
        if numel * 4 != bytes.len() {
            return Err(Error(format!(
                "literal byte length {} does not match shape {shape:?}",
                bytes.len()
            )));
        }
        Ok(Self { ty, shape: shape.to_vec(), bytes: bytes.to_vec() })
    }

    pub fn element_type(&self) -> ElementType {
        self.ty
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Reinterpret the stored bytes as `T` values.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.ty != T::ELEMENT_TYPE {
            return Err(Error(format!(
                "literal dtype {:?} read as {:?}",
                self.ty,
                T::ELEMENT_TYPE
            )));
        }
        let size = std::mem::size_of::<T>();
        Ok(self
            .bytes
            .chunks_exact(size)
            .map(|c| unsafe { std::ptr::read_unaligned(c.as_ptr() as *const T) })
            .collect())
    }

    /// Destructure a tuple literal. The stub never produces tuples (only a
    /// real execution does), so this always errors.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("tuple readback"))
    }
}

/// Stub device buffer (no storage — execution is unavailable anyway).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("device readback"))
    }
}

/// Stub PJRT client. Construction succeeds so host-only paths (registry
/// inspection, value conversion) work; compilation/execution fail clearly.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "cpu (offline xla stub)".to_string()
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _shape: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Ok(PjRtBuffer)
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("XLA compilation"))
    }
}

/// Stub loaded executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b<T: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PJRT execution"))
    }
}

/// Stub HLO module proto: parsing requires the real text parser.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<Self> {
        Err(Error(format!(
            "cannot parse {}: step-nm was built against the offline xla stub",
            path.as_ref().display()
        )))
    }
}

/// Stub computation wrapper.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let vals = [1.0f32, -2.5, 0.0, 3.25];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2, 2], &bytes)
                .unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vals);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn literal_rejects_bad_length() {
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::S32, &[3], &[0u8; 4])
                .is_err()
        );
    }

    #[test]
    fn execution_paths_error_cleanly() {
        let client = PjRtClient::cpu().unwrap();
        assert!(client.platform_name().contains("stub"));
        let exe_err = client.compile(&XlaComputation::from_proto(&HloModuleProto));
        assert!(format!("{:?}", exe_err.unwrap_err()).contains("offline xla stub"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
