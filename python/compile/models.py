"""Layer-2 model zoo: pure-JAX (no flax) forward passes + initializers.

Every model is a ``ModelSpec``: an ordered list of parameter specs (name,
shape, sparse-eligibility) plus ``apply(params, batch) -> logits`` and
``init(seed) -> params``. Parameters are plain ordered lists of jnp arrays so
the AOT artifacts have a stable, manifest-describable input layout for the
Rust runtime.

Sparse eligibility mirrors the paper's choices: Linear / attention projection
/ conv kernels are maskable; embeddings, layer norms, biases and heads stay
dense (BERT: "all the Linear modules"; GPT-2: "all the Conv1D modules";
ResNet/DenseNet: "all the Conv2D layers").
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    name: str
    shape: Tuple[int, ...]
    sparse: bool  # eligible for N:M masking (last axis grouped by M)


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    name: str
    params: Tuple[ParamSpec, ...]
    apply: Callable  # (params: List[Array], x) -> logits
    kind: str        # "classify" | "regress" | "lm"
    n_classes: int   # classes (classify), 1 (regress), vocab (lm)
    in_dim: int = 0  # flat feature-vector width (0 for token models)

    def init(self, seed: int) -> List[jax.Array]:
        key = jax.random.PRNGKey(seed)
        out = []
        for spec in self.params:
            key, sub = jax.random.split(key)
            out.append(_init_param(sub, spec))
        return out

    @property
    def sparse_indices(self) -> List[int]:
        return [i for i, p in enumerate(self.params) if p.sparse]

    @property
    def dim(self) -> int:
        return sum(math.prod(p.shape) for p in self.params)


def _init_param(key, spec: ParamSpec) -> jax.Array:
    shape = spec.shape
    lname = spec.name
    if lname.endswith("_b") or "bias" in lname or "ln_" in lname and lname.endswith("_beta"):
        return jnp.zeros(shape, jnp.float32)
    if "ln_" in lname and lname.endswith("_gamma"):
        return jnp.ones(shape, jnp.float32)
    if "emb" in lname:
        return 0.02 * jax.random.normal(key, shape, jnp.float32)
    # fan-in scaled init for weight matrices / conv kernels
    fan_in = math.prod(shape[:-1]) if len(shape) > 1 else shape[0]
    scale = 1.0 / math.sqrt(max(fan_in, 1))
    return scale * jax.random.normal(key, shape, jnp.float32)


# ---------------------------------------------------------------------------
# MLP (CIFAR-analog fast path)
# ---------------------------------------------------------------------------

def mlp(name: str, in_dim: int, hidden: Sequence[int], n_classes: int) -> ModelSpec:
    """ReLU MLP classifier. Hidden weight matrices are sparse-eligible."""
    sizes = [in_dim, *hidden, n_classes]
    specs: List[ParamSpec] = []
    for i in range(len(sizes) - 1):
        last = i == len(sizes) - 2
        specs.append(ParamSpec(f"fc{i}_w", (sizes[i], sizes[i + 1]), sparse=not last))
        specs.append(ParamSpec(f"fc{i}_b", (sizes[i + 1],), sparse=False))

    n_layers = len(sizes) - 1

    def apply(params: List[jax.Array], x: jax.Array) -> jax.Array:
        h = x.reshape(x.shape[0], -1)
        for i in range(n_layers):
            w, b = params[2 * i], params[2 * i + 1]
            h = h @ w + b
            if i != n_layers - 1:
                h = jax.nn.relu(h)
        return h

    return ModelSpec(name, tuple(specs), apply, "classify", n_classes, in_dim)


# ---------------------------------------------------------------------------
# CNN (ResNet18 / DenseNet121 analog: conv stacks + residual connections)
# ---------------------------------------------------------------------------

def cnn(name: str, channels: Sequence[int], n_classes: int,
        in_hw: int = 16, in_c: int = 3) -> ModelSpec:
    """Small residual CNN on NHWC images. Conv kernels are sparse-eligible
    (masked along the output-channel axis, matching the pinned last-axis
    convention)."""
    specs: List[ParamSpec] = [
        ParamSpec("stem_w", (3, 3, in_c, channels[0]), sparse=False),  # stem kept dense (first conv, as in SR-STE practice)
        ParamSpec("stem_b", (channels[0],), sparse=False),
    ]
    for i, (cin, cout) in enumerate(zip(channels[:-1], channels[1:])):
        specs += [
            ParamSpec(f"blk{i}_conv1_w", (3, 3, cin, cout), sparse=True),
            ParamSpec(f"blk{i}_conv1_b", (cout,), sparse=False),
            ParamSpec(f"blk{i}_conv2_w", (3, 3, cout, cout), sparse=True),
            ParamSpec(f"blk{i}_conv2_b", (cout,), sparse=False),
            ParamSpec(f"blk{i}_skip_w", (1, 1, cin, cout), sparse=False),
        ]
    specs += [
        ParamSpec("head_w", (channels[-1], n_classes), sparse=False),
        ParamSpec("head_b", (n_classes,), sparse=False),
    ]

    n_blocks = len(channels) - 1

    def conv(x, w, b=None, stride=1):
        y = jax.lax.conv_general_dilated(
            x, w, (stride, stride), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        return y if b is None else y + b

    def apply(params: List[jax.Array], x: jax.Array) -> jax.Array:
        x = x.reshape(x.shape[0], in_hw, in_hw, in_c)
        p = iter(params)
        h = jax.nn.relu(conv(x, next(p), next(p)))
        for i in range(n_blocks):
            w1, b1, w2, b2, ws = next(p), next(p), next(p), next(p), next(p)
            stride = 2 if i % 2 == 1 else 1
            y = jax.nn.relu(conv(h, w1, b1, stride))
            y = conv(y, w2, b2)
            h = jax.nn.relu(y + conv(h, ws, stride=stride))
        h = jnp.mean(h, axis=(1, 2))  # global average pool
        return h @ next(p) + next(p)

    return ModelSpec(name, tuple(specs), apply, "classify", n_classes,
                     in_hw * in_hw * in_c)


# ---------------------------------------------------------------------------
# Transformer blocks (shared by encoder / LM)
# ---------------------------------------------------------------------------

def _tf_layer_specs(prefix: str, d: int, d_ff: int) -> List[ParamSpec]:
    return [
        ParamSpec(f"{prefix}_wq", (d, d), sparse=True),
        ParamSpec(f"{prefix}_wk", (d, d), sparse=True),
        ParamSpec(f"{prefix}_wv", (d, d), sparse=True),
        ParamSpec(f"{prefix}_wo", (d, d), sparse=True),
        ParamSpec(f"{prefix}_ln1_gamma", (d,), sparse=False),
        ParamSpec(f"{prefix}_ln1_beta", (d,), sparse=False),
        ParamSpec(f"{prefix}_fc1_w", (d, d_ff), sparse=True),
        ParamSpec(f"{prefix}_fc1_b", (d_ff,), sparse=False),
        ParamSpec(f"{prefix}_fc2_w", (d_ff, d), sparse=True),
        ParamSpec(f"{prefix}_fc2_b", (d,), sparse=False),
        ParamSpec(f"{prefix}_ln2_gamma", (d,), sparse=False),
        ParamSpec(f"{prefix}_ln2_beta", (d,), sparse=False),
    ]


def _layernorm(x, gamma, beta, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return gamma * (x - mu) / jnp.sqrt(var + eps) + beta


def _tf_layer(h, p, n_heads: int, causal: bool):
    """Pre-LN transformer layer. ``p`` is an iterator over the 12 params."""
    wq, wk, wv, wo = next(p), next(p), next(p), next(p)
    g1, b1 = next(p), next(p)
    fc1w, fc1b, fc2w, fc2b = next(p), next(p), next(p), next(p)
    g2, b2 = next(p), next(p)

    bsz, seq, d = h.shape
    dh = d // n_heads
    x = _layernorm(h, g1, b1)
    q = (x @ wq).reshape(bsz, seq, n_heads, dh).transpose(0, 2, 1, 3)
    k = (x @ wk).reshape(bsz, seq, n_heads, dh).transpose(0, 2, 1, 3)
    v = (x @ wv).reshape(bsz, seq, n_heads, dh).transpose(0, 2, 1, 3)
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / math.sqrt(dh)
    if causal:
        mask = jnp.tril(jnp.ones((seq, seq), bool))
        att = jnp.where(mask, att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    ctx = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    ctx = ctx.transpose(0, 2, 1, 3).reshape(bsz, seq, d)
    h = h + ctx @ wo

    x = _layernorm(h, g2, b2)
    h = h + jax.nn.gelu(x @ fc1w + fc1b) @ fc2w + fc2b
    return h


def transformer_lm(name: str, vocab: int, d: int, n_layers: int,
                   n_heads: int, seq_len: int, d_ff: int | None = None) -> ModelSpec:
    """Decoder-only causal LM (GPT-2 analog). Sparse: all projection /
    feed-forward matrices (the Conv1D analogs); embeddings + head dense."""
    d_ff = d_ff or 4 * d
    specs: List[ParamSpec] = [
        ParamSpec("tok_emb", (vocab, d), sparse=False),
        ParamSpec("pos_emb", (seq_len, d), sparse=False),
    ]
    for i in range(n_layers):
        specs += _tf_layer_specs(f"l{i}", d, d_ff)
    specs += [
        ParamSpec("lnf_gamma", (d,), sparse=False),
        ParamSpec("lnf_beta", (d,), sparse=False),
        ParamSpec("head_w", (d, vocab), sparse=False),
    ]

    def apply(params: List[jax.Array], x: jax.Array) -> jax.Array:
        p = iter(params)
        tok, pos = next(p), next(p)
        h = tok[x] + pos[None, : x.shape[1]]
        for _ in range(n_layers):
            h = _tf_layer(h, p, n_heads, causal=True)
        h = _layernorm(h, next(p), next(p))
        return h @ next(p)  # [B, S, vocab]

    return ModelSpec(name, tuple(specs), apply, "lm", vocab)


def transformer_encoder(name: str, vocab: int, d: int, n_layers: int,
                        n_heads: int, seq_len: int, n_classes: int,
                        kind: str = "classify",
                        d_ff: int | None = None) -> ModelSpec:
    """Bidirectional encoder + CLS head (BERT analog). kind: classify|regress."""
    d_ff = d_ff or 4 * d
    specs: List[ParamSpec] = [
        ParamSpec("tok_emb", (vocab, d), sparse=False),
        ParamSpec("pos_emb", (seq_len, d), sparse=False),
    ]
    for i in range(n_layers):
        specs += _tf_layer_specs(f"l{i}", d, d_ff)
    specs += [
        ParamSpec("lnf_gamma", (d,), sparse=False),
        ParamSpec("lnf_beta", (d,), sparse=False),
        ParamSpec("head_w", (d, n_classes), sparse=False),
        ParamSpec("head_b", (n_classes,), sparse=False),
    ]

    def apply(params: List[jax.Array], x: jax.Array) -> jax.Array:
        p = iter(params)
        tok, pos = next(p), next(p)
        h = tok[x] + pos[None, : x.shape[1]]
        for _ in range(n_layers):
            h = _tf_layer(h, p, n_heads, causal=False)
        h = _layernorm(h, next(p), next(p))
        cls = h[:, 0]  # first token pools the sequence
        return cls @ next(p) + next(p)

    return ModelSpec(name, tuple(specs), apply, kind, n_classes)


# ---------------------------------------------------------------------------
# Registry of the configs the experiments use (see DESIGN.md SS3)
# ---------------------------------------------------------------------------

def registry() -> dict:
    return {
        # CIFAR analogs (Figs 1-5, 7, 8; Tables 1, 4)
        "mlp_cf10": mlp("mlp_cf10", 3 * 16 * 16, [512, 256], 10),
        "cnn_cf100": cnn("cnn_cf100", [32, 64, 64], 100),
        # BERT-Base / GLUE analogs (Table 2)
        "enc_glue2": transformer_encoder("enc_glue2", 512, 128, 2, 4, 32, 2),
        "enc_glue3": transformer_encoder("enc_glue3", 512, 128, 2, 4, 32, 3),
        "enc_stsb": transformer_encoder("enc_stsb", 512, 128, 2, 4, 32, 1,
                                        kind="regress"),
        # GPT-2 / WikiText analogs (Table 3) + WMT analog (Fig 6)
        "lm_wiki": transformer_lm("lm_wiki", 256, 128, 4, 4, 64),
        "lm_wmt": transformer_lm("lm_wmt", 128, 128, 2, 4, 48),
        # pallas cross-check config (tiny, static 2:4 kernels)
        "mlp_pallas": mlp("mlp_pallas", 64, [64], 10),
        # e2e example config: multi-layer LM for the end-to-end driver
        "lm_e2e": transformer_lm("lm_e2e", 256, 256, 6, 8, 128),
    }
