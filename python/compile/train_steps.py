"""Layer-2 train/eval step builders: one jitted function per (model, recipe).

Every builder returns an ``Artifact``: the step function, a description of
its flat input/output layout (what ``artifacts/manifest.json`` records for
the Rust runtime), and example arguments for lowering. The Rust coordinator
owns all state; each call is purely functional:

    inputs : params..., opt-state..., batch, scalars (lr, t, lam), n_vec
    outputs: params'..., opt-state'..., loss, telemetry scalars

Recipes (DESIGN.md SS2):
  dense_adam   Alg. 1 lines 2-9  (also STEP phase 1)
  dense_sgdm   momentum-SGD baseline (Fig 1)
  srste_adam   Eq (9) with Adam; lam == 0 gives plain STE (Fig 8 variant:
               run this after the switch point to "keep updating v")
  srste_sgdm   Eq (9) with momentum SGD (Fig 1)
  step_phase2  Alg. 1 lines 15-22: frozen v*, masked fwd, momentum-only
  asp_adam     ASP: masked fwd/bwd with gradients and weights projected onto
               the current support (prune-once-retrain semantics)
  eval         masked (or dense, n == m) forward + loss + raw metric sums

N is a *runtime* input (int32 vector, one entry per sparse tensor; see
ref.nm_mask_dynamic) so a single artifact serves uniform ratios, layer-wise
DominoSearch ratios, decaying-mask schedules and dense eval (n == m).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from .kernels import ref
from .models import ModelSpec

N_STATS = 4    # l1(v), l2(v), l1(dv), sum log|dv|
N_METRICS = 8  # recipe-independent raw metric sums (see eval builder)


@dataclasses.dataclass
class Artifact:
    name: str
    fn: Callable
    example_args: tuple
    input_names: List[str]
    output_names: List[str]
    meta: dict


# ---------------------------------------------------------------------------
# shared pieces
# ---------------------------------------------------------------------------

def _masks_of(model: ModelSpec, params: List[jax.Array], n_vec: jax.Array,
              m: int) -> List[jax.Array | None]:
    """Per-tensor N:M masks (None for dense tensors).

    Called OUTSIDE value_and_grad: masks are stop-gradient constants w.r.t.
    the step (Pi_t is a function of w_t but STE treats it as fixed), and
    keeping the argsort out of the differentiated region both avoids the
    sort-VJP and computes each mask exactly once per step.
    """
    masks: List[jax.Array | None] = []
    si = 0
    for spec, p in zip(model.params, params):
        if not spec.sparse:
            masks.append(None)
            continue
        flat2d = p.reshape(-1, p.shape[-1])
        mask = ref.nm_mask_dynamic(flat2d, n_vec[si], m).reshape(p.shape)
        masks.append(jax.lax.stop_gradient(mask))
        si += 1
    return masks


def _apply_masks(params: List[jax.Array], masks: List[jax.Array | None],
                 ste: bool) -> List[jax.Array]:
    """``ste=True``: straight-through (d(masked)/d(param) == I, Eq 8).
    ``ste=False``: plain product (pruned-coordinate gradients zeroed - ASP)."""
    out = []
    for p, mk in zip(params, masks):
        if mk is None:
            out.append(p)
        elif ste:
            out.append(p + jax.lax.stop_gradient(mk * p - p))
        else:
            out.append(mk * p)
    return out


def _loss_fn(model: ModelSpec):
    if model.kind == "classify":
        def loss(params, x, y):
            logits = model.apply(params, x)
            logp = jax.nn.log_softmax(logits, axis=-1)
            return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1))
    elif model.kind == "regress":
        def loss(params, x, y):
            pred = model.apply(params, x)[:, 0]
            return jnp.mean(jnp.square(pred - y))
    elif model.kind == "lm":
        def loss(params, x, y):
            logits = model.apply(params, x)
            logp = jax.nn.log_softmax(logits, axis=-1)
            nll = -jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
            return jnp.mean(nll)
    else:
        raise ValueError(model.kind)
    return loss


def _var_stats(v_new: List[jax.Array], v_old: List[jax.Array]):
    """Telemetry scalars for AutoSwitch: l1(v), l2(v), l1(dv), sum log|dv|."""
    l1 = sum(jnp.sum(jnp.abs(v)) for v in v_new)
    l2 = jnp.sqrt(sum(jnp.sum(jnp.square(v)) for v in v_new))
    dv_l1 = sum(jnp.sum(jnp.abs(a - b)) for a, b in zip(v_new, v_old))
    log_dv = sum(jnp.sum(jnp.log(jnp.abs(a - b) + 1e-38))
                 for a, b in zip(v_new, v_old))
    return jnp.stack([l1, l2, dv_l1, log_dv]).astype(jnp.float32)


def _batch_example(model: ModelSpec, batch: int, seq: int | None):
    if model.kind == "lm":
        x = jnp.zeros((batch, seq), jnp.int32)
        y = jnp.zeros((batch, seq), jnp.int32)
    elif model.kind == "regress":
        x = _x_example(model, batch, seq)
        y = jnp.zeros((batch,), jnp.float32)
    else:
        x = _x_example(model, batch, seq)
        y = jnp.zeros((batch,), jnp.int32)
    return x, y


def _x_example(model: ModelSpec, batch: int, seq: int | None):
    if seq is not None:  # token models
        return jnp.zeros((batch, seq), jnp.int32)
    return jnp.zeros((batch, model.in_dim), jnp.float32)


def _names(model: ModelSpec, prefix: str) -> List[str]:
    return [f"{prefix}.{p.name}" for p in model.params]


def _scalar(x, dtype=jnp.float32):
    return jnp.asarray([x], dtype)


# ---------------------------------------------------------------------------
# builders
# ---------------------------------------------------------------------------

def build_dense_adam(model: ModelSpec, batch: int, seq: int | None,
                     beta1=0.9, beta2=0.999, eps=1e-8) -> Artifact:
    """Dense Adam step (STEP phase 1). Emits variance telemetry."""
    loss_fn = _loss_fn(model)
    P = len(model.params)

    def fn(*args):
        params = list(args[:P])
        m = list(args[P:2 * P])
        v = list(args[2 * P:3 * P])
        x, y, lr, t = args[3 * P], args[3 * P + 1], args[3 * P + 2][0], args[3 * P + 3][0]
        loss, grads = jax.value_and_grad(loss_fn)(params, x, y)
        new_p, new_m, new_v = [], [], []
        for p, mi, vi, g in zip(params, m, v, grads):
            p1, m1, v1 = ref.adam_update(p, mi, vi, g, t, lr, beta1, beta2, eps)
            new_p.append(p1); new_m.append(m1); new_v.append(v1)
        stats = _var_stats(new_v, v)
        return (*new_p, *new_m, *new_v, loss[None], stats)

    x, y = _batch_example(model, batch, seq)
    zeros = [jnp.zeros(p.shape, jnp.float32) for p in model.params]
    ex = (*[jnp.zeros(p.shape, jnp.float32) for p in model.params],
          *zeros, *zeros, x, y, _scalar(1e-3), _scalar(1.0))
    return Artifact(
        f"{model.name}__dense_adam", fn, ex,
        _names(model, "p") + _names(model, "m") + _names(model, "v")
        + ["x", "y", "lr", "t"],
        _names(model, "p'") + _names(model, "m'") + _names(model, "v'")
        + ["loss", "stats"],
        {"recipe": "dense_adam", "model": model.name, "batch": batch,
         "beta1": beta1, "beta2": beta2, "eps": eps},
    )


def build_dense_sgdm(model: ModelSpec, batch: int, seq: int | None,
                     momentum=0.9) -> Artifact:
    """Dense momentum-SGD step (Fig 1 left column)."""
    loss_fn = _loss_fn(model)
    P = len(model.params)

    def fn(*args):
        params = list(args[:P])
        buf = list(args[P:2 * P])
        x, y, lr = args[2 * P], args[2 * P + 1], args[2 * P + 2][0]
        loss, grads = jax.value_and_grad(loss_fn)(params, x, y)
        new_p, new_b = [], []
        for p, b, g in zip(params, buf, grads):
            p1, b1 = ref.sgdm_update(p, b, g, lr, momentum)
            new_p.append(p1); new_b.append(b1)
        return (*new_p, *new_b, loss[None])

    x, y = _batch_example(model, batch, seq)
    zeros = [jnp.zeros(p.shape, jnp.float32) for p in model.params]
    ex = (*zeros, *zeros, x, y, _scalar(1e-2))
    return Artifact(
        f"{model.name}__dense_sgdm", fn, ex,
        _names(model, "p") + _names(model, "b") + ["x", "y", "lr"],
        _names(model, "p'") + _names(model, "b'") + ["loss"],
        {"recipe": "dense_sgdm", "model": model.name, "batch": batch,
         "momentum": momentum},
    )


def build_srste_adam(model: ModelSpec, batch: int, seq: int | None, m_sparse: int,
                     beta1=0.9, beta2=0.999, eps=1e-8) -> Artifact:
    """SR-STE with Adam (Eq 9). lam == 0 -> plain STE. Emits telemetry."""
    loss_fn = _loss_fn(model)
    P = len(model.params)
    S = len(model.sparse_indices)

    def fn(*args):
        params = list(args[:P])
        m = list(args[P:2 * P])
        v = list(args[2 * P:3 * P])
        x, y = args[3 * P], args[3 * P + 1]
        lr, t, lam = args[3 * P + 2][0], args[3 * P + 3][0], args[3 * P + 4][0]
        n_vec = args[3 * P + 5]

        masks = _masks_of(model, params, n_vec, m_sparse)

        def masked_loss(ps):
            return loss_fn(_apply_masks(ps, masks, ste=True), x, y)

        loss, grads = jax.value_and_grad(masked_loss)(params)
        new_p, new_m, new_v = [], [], []
        for p, mi, vi, g, mk in zip(params, m, v, grads, masks):
            if mk is not None:
                g = ref.srste_refine(g, p, mk, lam)  # Eq (9)
            p1, m1, v1 = ref.adam_update(p, mi, vi, g, t, lr, beta1, beta2, eps)
            new_p.append(p1); new_m.append(m1); new_v.append(v1)
        stats = _var_stats(new_v, v)
        return (*new_p, *new_m, *new_v, loss[None], stats)

    x, y = _batch_example(model, batch, seq)
    zeros = [jnp.zeros(p.shape, jnp.float32) for p in model.params]
    ex = (*zeros, *zeros, *zeros, x, y, _scalar(1e-3), _scalar(1.0),
          _scalar(2e-4), jnp.full((S,), 2, jnp.int32))
    return Artifact(
        f"{model.name}__srste_adam_m{m_sparse}", fn, ex,
        _names(model, "p") + _names(model, "m") + _names(model, "v")
        + ["x", "y", "lr", "t", "lam", "n_vec"],
        _names(model, "p'") + _names(model, "m'") + _names(model, "v'")
        + ["loss", "stats"],
        {"recipe": "srste_adam", "model": model.name, "batch": batch,
         "m": m_sparse, "beta1": beta1, "beta2": beta2, "eps": eps},
    )


def build_srste_sgdm(model: ModelSpec, batch: int, seq: int | None,
                     m_sparse: int, momentum=0.9) -> Artifact:
    """SR-STE with momentum SGD (the regime where SR-STE works; Fig 1)."""
    loss_fn = _loss_fn(model)
    P = len(model.params)
    S = len(model.sparse_indices)

    def fn(*args):
        params = list(args[:P])
        buf = list(args[P:2 * P])
        x, y = args[2 * P], args[2 * P + 1]
        lr, lam = args[2 * P + 2][0], args[2 * P + 3][0]
        n_vec = args[2 * P + 4]

        masks = _masks_of(model, params, n_vec, m_sparse)

        def masked_loss(ps):
            return loss_fn(_apply_masks(ps, masks, ste=True), x, y)

        loss, grads = jax.value_and_grad(masked_loss)(params)
        new_p, new_b = [], []
        for p, b, g, mk in zip(params, buf, grads, masks):
            if mk is not None:
                g = ref.srste_refine(g, p, mk, lam)
            p1, b1 = ref.sgdm_update(p, b, g, lr, momentum)
            new_p.append(p1); new_b.append(b1)
        return (*new_p, *new_b, loss[None])

    x, y = _batch_example(model, batch, seq)
    zeros = [jnp.zeros(p.shape, jnp.float32) for p in model.params]
    ex = (*zeros, *zeros, x, y, _scalar(1e-2), _scalar(2e-4),
          jnp.full((S,), 2, jnp.int32))
    return Artifact(
        f"{model.name}__srste_sgdm_m{m_sparse}", fn, ex,
        _names(model, "p") + _names(model, "b") + ["x", "y", "lr", "lam", "n_vec"],
        _names(model, "p'") + _names(model, "b'") + ["loss"],
        {"recipe": "srste_sgdm", "model": model.name, "batch": batch,
         "m": m_sparse, "momentum": momentum},
    )


def build_step_phase2(model: ModelSpec, batch: int, seq: int | None,
                      m_sparse: int, beta1=0.9, eps=1e-8) -> Artifact:
    """STEP mask-learning phase (Alg. 1 lines 15-22): v* frozen precondition.

    v* enters as input but is NOT an output - freezing is structural. The
    optional SR-STE refinement (lam) composes with the frozen precondition.
    """
    loss_fn = _loss_fn(model)
    P = len(model.params)
    S = len(model.sparse_indices)

    def fn(*args):
        params = list(args[:P])
        m = list(args[P:2 * P])
        v_star = list(args[2 * P:3 * P])
        x, y = args[3 * P], args[3 * P + 1]
        lr, t, lam = args[3 * P + 2][0], args[3 * P + 3][0], args[3 * P + 4][0]
        n_vec = args[3 * P + 5]

        masks = _masks_of(model, params, n_vec, m_sparse)

        def masked_loss(ps):
            return loss_fn(_apply_masks(ps, masks, ste=True), x, y)

        loss, grads = jax.value_and_grad(masked_loss)(params)
        new_p, new_m = [], []
        for p, mi, vs, g, mk in zip(params, m, v_star, grads, masks):
            if mk is not None:
                g = ref.srste_refine(g, p, mk, lam)
            p1, m1 = ref.step_phase2_update(p, mi, vs, g, t, lr, beta1, eps)
            new_p.append(p1); new_m.append(m1)
        return (*new_p, *new_m, loss[None])

    x, y = _batch_example(model, batch, seq)
    zeros = [jnp.zeros(p.shape, jnp.float32) for p in model.params]
    ones = [jnp.ones(p.shape, jnp.float32) for p in model.params]
    ex = (*zeros, *zeros, *ones, x, y, _scalar(1e-3), _scalar(1.0),
          _scalar(0.0), jnp.full((S,), 2, jnp.int32))
    return Artifact(
        f"{model.name}__step_phase2_m{m_sparse}", fn, ex,
        _names(model, "p") + _names(model, "m") + _names(model, "vstar")
        + ["x", "y", "lr", "t", "lam", "n_vec"],
        _names(model, "p'") + _names(model, "m'") + ["loss"],
        {"recipe": "step_phase2", "model": model.name, "batch": batch,
         "m": m_sparse, "beta1": beta1, "eps": eps},
    )


def build_asp_adam(model: ModelSpec, batch: int, seq: int | None,
                   m_sparse: int, beta1=0.9, beta2=0.999, eps=1e-8) -> Artifact:
    """ASP-style step: plain product masking (no STE), gradients and the
    updated weights both projected onto the support, so pruned coordinates
    stay at zero and the mask is effectively fixed after the first step."""
    loss_fn = _loss_fn(model)
    P = len(model.params)
    S = len(model.sparse_indices)

    def fn(*args):
        params = list(args[:P])
        m = list(args[P:2 * P])
        v = list(args[2 * P:3 * P])
        x, y = args[3 * P], args[3 * P + 1]
        lr, t = args[3 * P + 2][0], args[3 * P + 3][0]
        n_vec = args[3 * P + 4]

        masks = _masks_of(model, params, n_vec, m_sparse)

        def masked_loss(ps):
            return loss_fn(_apply_masks(ps, masks, ste=False), x, y)

        loss, grads = jax.value_and_grad(masked_loss)(params)
        new_p, new_m, new_v = [], [], []
        for p, mi, vi, g, mk in zip(params, m, v, grads, masks):
            p1, m1, v1 = ref.adam_update(p, mi, vi, g, t, lr, beta1, beta2, eps)
            if mk is not None:
                p1 = mk * p1  # project back onto the support
            new_p.append(p1); new_m.append(m1); new_v.append(v1)
        stats = _var_stats(new_v, v)
        return (*new_p, *new_m, *new_v, loss[None], stats)

    x, y = _batch_example(model, batch, seq)
    zeros = [jnp.zeros(p.shape, jnp.float32) for p in model.params]
    ex = (*zeros, *zeros, *zeros, x, y, _scalar(1e-3), _scalar(1.0),
          jnp.full((S,), 2, jnp.int32))
    return Artifact(
        f"{model.name}__asp_adam_m{m_sparse}", fn, ex,
        _names(model, "p") + _names(model, "m") + _names(model, "v")
        + ["x", "y", "lr", "t", "n_vec"],
        _names(model, "p'") + _names(model, "m'") + _names(model, "v'")
        + ["loss", "stats"],
        {"recipe": "asp_adam", "model": model.name, "batch": batch,
         "m": m_sparse, "beta1": beta1, "beta2": beta2, "eps": eps},
    )


def build_eval(model: ModelSpec, batch: int, seq: int | None,
               m_sparse: int) -> Artifact:
    """Masked evaluation step (n == m gives dense eval).

    Outputs loss plus a fixed-width vector of raw metric sums the Rust side
    reduces across batches:
      classify: [correct, count, tp, fp, tn, fn, 0, 0]
                (confusion counts w.r.t. class 1, for F1/MCC on binary tasks)
      regress : [sum_p, sum_y, sum_pp, sum_yy, sum_py, count, sse, 0]
      lm      : [total_nll, tokens, 0, ...]
    """
    P = len(model.params)
    S = len(model.sparse_indices)

    def fn(*args):
        params = list(args[:P])
        x, y, n_vec = args[P], args[P + 1], args[P + 2]
        masks = _masks_of(model, params, n_vec, m_sparse)
        out = model.apply(_apply_masks(params, masks, ste=False), x)
        z = jnp.zeros((), jnp.float32)
        if model.kind == "classify":
            logp = jax.nn.log_softmax(out, axis=-1)
            loss = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1))
            pred = jnp.argmax(out, axis=-1)
            correct = jnp.sum(pred == y).astype(jnp.float32)
            # confusion counts w.r.t. class 1 (meaningful for binary tasks;
            # harmless extra sums otherwise) - feeds F1 / Matthews corr.
            pp = (pred == 1)
            yp = (y == 1)
            tp = jnp.sum(pp & yp).astype(jnp.float32)
            fp = jnp.sum(pp & ~yp).astype(jnp.float32)
            fn_ = jnp.sum(~pp & yp).astype(jnp.float32)
            tn = jnp.sum(~pp & ~yp).astype(jnp.float32)
            metrics = jnp.stack([correct, jnp.asarray(y.shape[0], jnp.float32),
                                 tp, fp, tn, fn_, z, z])
        elif model.kind == "regress":
            pred = out[:, 0]
            loss = jnp.mean(jnp.square(pred - y))
            metrics = jnp.stack([
                jnp.sum(pred), jnp.sum(y), jnp.sum(pred * pred),
                jnp.sum(y * y), jnp.sum(pred * y),
                jnp.asarray(y.shape[0], jnp.float32),
                jnp.sum(jnp.square(pred - y)), z])
        else:  # lm
            logp = jax.nn.log_softmax(out, axis=-1)
            nll = -jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
            loss = jnp.mean(nll)
            metrics = jnp.stack([jnp.sum(nll),
                                 jnp.asarray(nll.size, jnp.float32),
                                 z, z, z, z, z, z])
        return (loss[None], metrics)

    x, y = _batch_example(model, batch, seq)
    zeros = [jnp.zeros(p.shape, jnp.float32) for p in model.params]
    ex = (*zeros, x, y, jnp.full((S,), m_sparse, jnp.int32))
    return Artifact(
        f"{model.name}__eval_m{m_sparse}", fn, ex,
        _names(model, "p") + ["x", "y", "n_vec"],
        ["loss", "metrics"],
        {"recipe": "eval", "model": model.name, "batch": batch, "m": m_sparse,
         "kind": model.kind},
    )


def build_srste_adam_pallas(model: ModelSpec, batch: int, seq: int | None,
                            n_sparse: int, m_sparse: int,
                            beta1=0.9, beta2=0.999, eps=1e-8) -> Artifact:
    """Kernel-bearing variant of srste_adam: the N:M mask and the fused
    optimizer updates run through the Pallas kernels (interpret mode) so the
    L1 kernels lower into the artifact. Static (n, m) - the kernels use
    top-k-style static selection. Verified equal to the jnp variant by
    python/tests and by the Rust integration test."""
    from .kernels.nm_mask import nm_mask as pallas_nm_mask
    from .kernels.optim_update import adam_update as pallas_adam
    from .kernels.optim_update import srste_refine as pallas_srste

    loss_fn = _loss_fn(model)
    P = len(model.params)

    def fn(*args):
        params = list(args[:P])
        m = list(args[P:2 * P])
        v = list(args[2 * P:3 * P])
        x, y = args[3 * P], args[3 * P + 1]
        lr, t, lam = args[3 * P + 2][0], args[3 * P + 3][0], args[3 * P + 4][0]

        def masks_of(ps):
            out = []
            for spec, p in zip(model.params, ps):
                if spec.sparse:
                    flat2d = p.reshape(-1, p.shape[-1])
                    mk = pallas_nm_mask(flat2d, n_sparse, m_sparse).reshape(p.shape)
                    out.append(jax.lax.stop_gradient(mk))
                else:
                    out.append(None)
            return out

        masks = masks_of(params)

        def masked_loss(ps):
            mp = [p if mk is None else p + jax.lax.stop_gradient(mk * p - p)
                  for p, mk in zip(ps, masks)]
            return loss_fn(mp, x, y)

        loss, grads = jax.value_and_grad(masked_loss)(params)
        new_p, new_m, new_v = [], [], []
        for p, mi, vi, g, mk in zip(params, m, v, grads, masks):
            shape = p.shape
            if mk is not None:
                g = pallas_srste(g.reshape(-1), p.reshape(-1),
                                 mk.reshape(-1), lam).reshape(shape)
            p1, m1, v1 = pallas_adam(p.reshape(-1), mi.reshape(-1),
                                     vi.reshape(-1), g.reshape(-1), t, lr,
                                     beta1, beta2, eps)
            new_p.append(p1.reshape(shape))
            new_m.append(m1.reshape(shape))
            new_v.append(v1.reshape(shape))
        stats = _var_stats(new_v, v)
        return (*new_p, *new_m, *new_v, loss[None], stats)

    x, y = _batch_example(model, batch, seq)
    zeros = [jnp.zeros(p.shape, jnp.float32) for p in model.params]
    ex = (*zeros, *zeros, *zeros, x, y, _scalar(1e-3), _scalar(1.0), _scalar(2e-4))
    return Artifact(
        f"{model.name}__srste_adam_pallas_n{n_sparse}m{m_sparse}", fn, ex,
        _names(model, "p") + _names(model, "m") + _names(model, "v")
        + ["x", "y", "lr", "t", "lam"],
        _names(model, "p'") + _names(model, "m'") + _names(model, "v'")
        + ["loss", "stats"],
        {"recipe": "srste_adam_pallas", "model": model.name, "batch": batch,
         "n": n_sparse, "m": m_sparse, "beta1": beta1, "beta2": beta2,
         "eps": eps},
    )
