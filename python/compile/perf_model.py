"""L1 structural performance model: VMEM footprint + MXU-utilization
estimates for the Pallas kernels' BlockSpecs.

interpret=True gives CPU-numpy timings only (not a TPU proxy), so per
DESIGN.md SSPerf the kernel layer is optimized *structurally*: every tile
must fit VMEM with double-buffering headroom, and the masked matmul should
keep the MXU systolic array busy. This script prints the model for the
shipped block shapes and flags violations; `python -m compile.perf_model`.

TPUv4-class budgets assumed: 16 MiB VMEM/core, 128x128 MXU @ 275 TFLOP/s
bf16, ~1.2 TB/s HBM.
"""

from __future__ import annotations

import argparse

VMEM_BYTES = 16 * 2**20
MXU_DIM = 128
PEAK_BF16_TFLOPS = 275.0
HBM_GBPS = 1200.0


def tile_bytes(shape, dtype_bytes=4):
    n = 1
    for d in shape:
        n *= d
    return n * dtype_bytes


def fmt_mib(b):
    return f"{b / 2**20:.2f} MiB"


def nm_mask_model(rows=256, cols=512, m=4, dtype_bytes=4):
    """nm_mask kernel (nm_mask.py): w tile in, mask tile out, VPU-bound.

    Selection is N rounds of lane-parallel argmax-and-exclude over the minor
    axis: elementwise compares/selects -> VPU. Roofline is HBM-bound
    (2 tensors moved, O(N*M) flops per element).
    """
    w = tile_bytes((rows, cols), dtype_bytes)
    mask = tile_bytes((rows, cols), dtype_bytes)
    scratch = tile_bytes((rows, cols // m, m), 1)  # bool selected
    total = w + mask + scratch
    # double-buffered streaming: 2x in-flight
    vmem = 2 * total
    bytes_moved = w + mask
    est_time_s = bytes_moved / (HBM_GBPS * 1e9)
    return {
        "kernel": f"nm_mask tile {rows}x{cols} (M={m})",
        "vmem": vmem,
        "ok": vmem <= VMEM_BYTES,
        "bound": "HBM (streaming)",
        "est_us_per_tile": est_time_s * 1e6,
    }


def masked_matmul_model(bm=128, bn=128, bk=512, n=2, m=4, dtype_bytes=2):
    """masked_matmul: x[bm,bk] @ (Pi*w)[bk,bn] accumulated over a K grid.

    The mask fuses into the LHS load (the Ampere sparse-tensor-core analog:
    the MXU consumes already-masked tiles; Pi never round-trips to HBM).
    MXU utilization estimate = useful MACs / (MXU-peak MACs in the tile
    time), where the masked weights carry n/m useful density but occupy the
    full tile (structured sparsity on TPU has no skip path - the win is
    model-size + the fused mask, not fewer MACs; we report both the dense
    utilization and the effective-useful fraction).
    """
    x = tile_bytes((bm, bk), dtype_bytes)
    w = tile_bytes((bk, bn), dtype_bytes)
    mask = tile_bytes((bk, bn), 1)
    acc = tile_bytes((bm, bn), 4)  # f32 accumulator
    vmem = 2 * (x + w + mask) + acc  # double-buffer inputs, single acc
    macs = bm * bn * bk
    # MXU does 128x128x(8 per cycle-ish); utilization from dimension padding
    def pad(d):
        return -(-d // MXU_DIM) * MXU_DIM
    util_dims = (bm * bn * bk) / (pad(bm) * pad(bn) * bk)
    flops = 2 * macs
    est_time_s = flops / (PEAK_BF16_TFLOPS * 1e12 * util_dims)
    hbm_time = (x + w + mask) / (HBM_GBPS * 1e9)
    bound = "MXU" if est_time_s > hbm_time else "HBM"
    return {
        "kernel": f"masked_matmul tile {bm}x{bn}x{bk} ({n}:{m} bf16)",
        "vmem": vmem,
        "ok": vmem <= VMEM_BYTES,
        "bound": bound,
        "mxu_util_dense": util_dims,
        "useful_frac": n / m,
        "est_us_per_tile": max(est_time_s, hbm_time) * 1e6,
    }


def optim_update_model(block=1 << 16, n_state=4, dtype_bytes=4):
    """Fused optimizer updates: pure streaming, one HBM round-trip per state
    tensor per step (the fusion guarantee the kernel makes explicit)."""
    per = tile_bytes((block,), dtype_bytes)
    vmem = 2 * n_state * per * 2  # in+out, double-buffered
    bytes_moved = 2 * n_state * per
    return {
        "kernel": f"adam/step2 update block {block} ({n_state} tensors)",
        "vmem": vmem,
        "ok": vmem <= VMEM_BYTES,
        "bound": "HBM (streaming)",
        "est_us_per_tile": bytes_moved / (HBM_GBPS * 1e9) * 1e6,
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.parse_args()
    rows = [
        nm_mask_model(256, 512, 4),
        nm_mask_model(256, 512, 32),
        masked_matmul_model(128, 128, 512),
        masked_matmul_model(256, 256, 1024),
        masked_matmul_model(128, 128, 512, n=1, m=16),
        optim_update_model(),
    ]
    print(f"{'kernel':<44} {'VMEM':>10} {'fits':>5} {'bound':>16} {'est/tile':>10}")
    for r in rows:
        extra = ""
        if "mxu_util_dense" in r:
            extra = (f"  mxu_util={r['mxu_util_dense']*100:.0f}%"
                     f" useful={r['useful_frac']*100:.0f}%")
        print(f"{r['kernel']:<44} {fmt_mib(r['vmem']):>10} "
              f"{'yes' if r['ok'] else 'NO':>5} {r['bound']:>16} "
              f"{r['est_us_per_tile']:>8.2f}us{extra}")
    bad = [r for r in rows if not r["ok"]]
    if bad:
        raise SystemExit(f"{len(bad)} tile configs exceed VMEM")
    print("\nall tile configs fit 16 MiB VMEM with double buffering ✓")


if __name__ == "__main__":
    main()
