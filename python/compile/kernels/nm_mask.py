"""Pallas kernel: N:M structured-sparsity mask (the paper's Pi_t).

TPU adaptation of the Ampere 2:4 pruning primitive (DESIGN.md
SSHardware-Adaptation): the weight matrix is tiled into VMEM-resident blocks
via BlockSpec; within a block the M-group top-N selection runs as N rounds of
a vectorized argmax-and-exclude sweep on the VPU (no data-dependent gather,
no top_k custom call - every round is a lane-parallel compare/select over the
minor axis, which is how this maps onto the 8x128 vector unit).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so interpret mode is both the correctness path and the form in
which the kernel lowers into the AOT HLO artifacts.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _nm_mask_kernel(w_ref, mask_ref, *, n: int, m: int):
    """Mask one (rows, cols) VMEM tile. cols % m == 0, n/m static."""
    w = w_ref[...]
    rows, cols = w.shape
    groups = w.reshape(rows, cols // m, m)
    mag = jnp.abs(groups)
    selected = jnp.zeros_like(mag, dtype=jnp.bool_)
    # N rounds of argmax-and-exclude. Tie-break: argmax returns the lowest
    # index, matching jax.lax.top_k stability (pinned in ref.nm_mask).
    neg = jnp.asarray(-1.0, mag.dtype)
    for _ in range(n):
        cand = jnp.where(selected, neg, mag)
        idx = jnp.argmax(cand, axis=-1)  # [rows, cols//m]
        hit = jax.nn.one_hot(idx, m, dtype=jnp.bool_)
        selected = jnp.logical_or(selected, hit)
    mask_ref[...] = selected.reshape(rows, cols).astype(w.dtype)


def nm_mask(w: jax.Array, n: int, m: int,
            block_rows: int = 256, block_cols: int = 512) -> jax.Array:
    """Binary N:M mask of ``w`` (2-D, last-axis groups of M), Pallas-tiled.

    Tile columns are rounded to a multiple of M so no group straddles a tile
    boundary; tiles are clamped to the array so small inputs still work.
    """
    if w.ndim != 2:
        raise ValueError(f"nm_mask kernel expects 2-D weights, got {w.shape}")
    if w.shape[-1] % m != 0:
        raise ValueError(f"last dim {w.shape[-1]} not divisible by M={m}")
    if not (1 <= n <= m):
        raise ValueError(f"need 1 <= N <= M, got N={n} M={m}")
    rows, cols = w.shape
    br = min(block_rows, rows)
    bc = min(block_cols - block_cols % m or m, cols)
    if cols % bc != 0 or rows % br != 0:
        # Fall back to one whole-array tile for awkward shapes; still a
        # pallas_call so the lowering path is identical.
        br, bc = rows, cols
    grid = (rows // br, cols // bc)
    return pl.pallas_call(
        functools.partial(_nm_mask_kernel, n=n, m=m),
        out_shape=jax.ShapeDtypeStruct(w.shape, w.dtype),
        grid=grid,
        in_specs=[pl.BlockSpec((br, bc), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        interpret=True,
    )(w)


def apply_mask(w: jax.Array, n: int, m: int, **kw) -> jax.Array:
    """Pi .* w via the mask kernel."""
    return nm_mask(w, n, m, **kw) * w
