"""Pallas kernels: fused optimizer updates (Adam, STEP phase 2, SR-STE refine).

These are the per-parameter elementwise hot loops of Algorithm 1. On TPU they
are VPU-bound streaming kernels: each grid step pulls one VMEM tile of every
state tensor, applies the fused update, and writes back - one HBM round-trip
per tensor per step instead of one per intermediate (what an unfused jnp
expression chain would do before XLA fusion; the kernel makes the fusion
explicit and guarantees it).

Scalars (lr, t, lambda) arrive as (1, 1) arrays so the same artifact serves
every step index / schedule value - the Rust coordinator feeds them per step.
``interpret=True`` as everywhere (CPU PJRT cannot run Mosaic).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _grid_1d(size: int, block: int):
    if size % block:
        block = size
    return (size // block,), block


# ---------------------------------------------------------------------------
# Dense Adam (Alg. 1 lines 4-8 / Eqs 3-7)
# ---------------------------------------------------------------------------

def _adam_kernel(w_ref, m_ref, v_ref, g_ref, lr_ref, t_ref,
                 w_out, m_out, v_out, *, beta1: float, beta2: float,
                 eps: float):
    g = g_ref[...]
    m1 = beta1 * m_ref[...] + (1.0 - beta1) * g
    v1 = beta2 * v_ref[...] + (1.0 - beta2) * g * g
    t = t_ref[0, 0]
    mhat = m1 / (1.0 - jnp.power(jnp.asarray(beta1, g.dtype), t))
    vhat = v1 / (1.0 - jnp.power(jnp.asarray(beta2, g.dtype), t))
    w_out[...] = w_ref[...] - lr_ref[0, 0] * mhat / (jnp.sqrt(vhat) + eps)
    m_out[...] = m1
    v_out[...] = v1


def adam_update(w, m, v, g, t, lr, beta1=0.9, beta2=0.999, eps=1e-8,
                block: int = 4096):
    """Fused dense-Adam step over a flat [d] parameter tensor.

    ``t`` is the 1-based step (traced scalar ok); returns (w', m', v').
    """
    d = w.shape[0]
    grid, blk = _grid_1d(d, block)
    lr_a = jnp.full((1, 1), lr, w.dtype)
    t_a = jnp.full((1, 1), t, w.dtype)
    flat = pl.BlockSpec((blk,), lambda i: (i,))
    scal = pl.BlockSpec((1, 1), lambda i: (0, 0))
    out = jax.ShapeDtypeStruct((d,), w.dtype)
    return pl.pallas_call(
        functools.partial(_adam_kernel, beta1=beta1, beta2=beta2, eps=eps),
        out_shape=(out, out, out),
        grid=grid,
        in_specs=[flat, flat, flat, flat, scal, scal],
        out_specs=(flat, flat, flat),
        interpret=True,
    )(w, m, v, g, lr_a, t_a)


# ---------------------------------------------------------------------------
# STEP phase 2 (Alg. 1 lines 18-20): frozen v*, momentum-only update
# ---------------------------------------------------------------------------

def _step2_kernel(w_ref, m_ref, vstar_ref, g_ref, lr_ref, t_ref,
                  w_out, m_out, *, beta1: float, eps: float):
    g = g_ref[...]
    m1 = beta1 * m_ref[...] + (1.0 - beta1) * g
    t = t_ref[0, 0]
    mhat = m1 / (1.0 - jnp.power(jnp.asarray(beta1, g.dtype), t))
    w_out[...] = w_ref[...] - lr_ref[0, 0] * mhat / jnp.sqrt(vstar_ref[...] + eps)
    m_out[...] = m1


def step_phase2_update(w, m, v_star, g, t, lr, beta1=0.9, eps=1e-8,
                       block: int = 4096):
    """Fused STEP mask-learning-phase step. v* is read-only (frozen).

    eps sits *inside* the sqrt, exactly as Alg. 1 line 20. Returns (w', m').
    """
    d = w.shape[0]
    grid, blk = _grid_1d(d, block)
    lr_a = jnp.full((1, 1), lr, w.dtype)
    t_a = jnp.full((1, 1), t, w.dtype)
    flat = pl.BlockSpec((blk,), lambda i: (i,))
    scal = pl.BlockSpec((1, 1), lambda i: (0, 0))
    out = jax.ShapeDtypeStruct((d,), w.dtype)
    return pl.pallas_call(
        functools.partial(_step2_kernel, beta1=beta1, eps=eps),
        out_shape=(out, out),
        grid=grid,
        in_specs=[flat, flat, flat, flat, scal, scal],
        out_specs=(flat, flat),
        interpret=True,
    )(w, m, v_star, g, lr_a, t_a)


# ---------------------------------------------------------------------------
# SR-STE gradient refinement (Eq 9)
# ---------------------------------------------------------------------------

def _srste_kernel(g_ref, w_ref, mask_ref, lam_ref, out_ref):
    out_ref[...] = g_ref[...] + lam_ref[0, 0] * (1.0 - mask_ref[...]) * w_ref[...]


def srste_refine(g, w, mask, lam, block: int = 4096):
    """Fused SR-STE refinement g + lam*(1-Pi).*w over flat [d] tensors."""
    d = g.shape[0]
    grid, blk = _grid_1d(d, block)
    lam_a = jnp.full((1, 1), lam, g.dtype)
    flat = pl.BlockSpec((blk,), lambda i: (i,))
    scal = pl.BlockSpec((1, 1), lambda i: (0, 0))
    return pl.pallas_call(
        _srste_kernel,
        out_shape=jax.ShapeDtypeStruct((d,), g.dtype),
        grid=grid,
        in_specs=[flat, flat, flat, scal],
        out_specs=flat,
        interpret=True,
    )(g, w, mask, lam_a)
