"""Pure-jnp reference oracle for every Pallas kernel in this package.

These functions define the *semantics* each kernel must match bit-for-bit
(masks) or to float tolerance (arithmetic). pytest sweeps the kernels against
these with hypothesis; the Rust integration tests compare the HLO artifacts
against the same math re-implemented in ``rust/src/optim``.

Conventions
-----------
* N:M sparsity groups are taken along the **last** axis of the weight tensor,
  which must be divisible by M. "N:M" keeps the N largest-|w| entries of every
  contiguous group of M (ties broken by lowest index, matching
  ``jax.lax.top_k``).
* Adam follows Kingma & Ba exactly as restated in the paper's Eqs (2)-(7),
  with the paper's step convention: at step ``t`` (1-based) bias correction
  divides by ``1 - beta^t``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# N:M masks
# ---------------------------------------------------------------------------

def nm_mask(w: jax.Array, n: int, m: int) -> jax.Array:
    """Return the binary N:M mask Pi for ``w`` (last-axis groups of M).

    Keeps the N largest-magnitude entries in each group of M consecutive
    elements along the last axis. Ties: lowest index wins (top_k semantics).
    """
    if w.shape[-1] % m != 0:
        raise ValueError(f"last dim {w.shape[-1]} not divisible by M={m}")
    if not (1 <= n <= m):
        raise ValueError(f"need 1 <= N <= M, got N={n} M={m}")
    groups = w.reshape(*w.shape[:-1], w.shape[-1] // m, m)
    mag = jnp.abs(groups)
    # top_k is stable: on ties it prefers lower indices.
    _, idx = jax.lax.top_k(mag, n)
    mask_groups = jnp.zeros_like(groups, dtype=w.dtype)
    mask_groups = jnp.put_along_axis(
        mask_groups, idx, jnp.ones_like(idx, dtype=w.dtype), axis=-1,
        inplace=False,
    )
    return mask_groups.reshape(w.shape)


def apply_mask(w: jax.Array, n: int, m: int) -> jax.Array:
    """Pi .* w."""
    return nm_mask(w, n, m) * w


def nm_mask_dynamic(w: jax.Array, n: jax.Array, m: int) -> jax.Array:
    """N:M mask where N is a *traced* int scalar (same semantics as nm_mask).

    Rank-based: within each M-group an entry's rank is the count of strictly
    larger magnitudes plus the count of equal magnitudes at lower index
    (stable, so ties go to the lower index exactly like top_k); keep
    rank < n. This lets a single AOT artifact serve every N (the Rust
    coordinator feeds n per layer per step: layer-wise DominoSearch ratios,
    decaying-mask schedules, and n == m for dense eval all reuse one
    executable).

    The pairwise-comparison form (O(M²) vectorized compares on [.., M, M])
    replaced a double-argsort implementation in the perf pass: bit-identical
    output, ~15× faster on the CPU backend and fusion-friendly everywhere
    (EXPERIMENTS.md §Perf).
    """
    if w.shape[-1] % m != 0:
        raise ValueError(f"last dim {w.shape[-1]} not divisible by M={m}")
    groups = w.reshape(*w.shape[:-1], w.shape[-1] // m, m)
    mag = jnp.abs(groups)
    a = mag[..., :, None]  # [.., m, 1] — the entry being ranked
    b = mag[..., None, :]  # [.., 1, m] — its group
    greater = (b > a).sum(axis=-1)
    idx = jnp.arange(m)
    eq_lower = ((b == a) & (idx[None, :] < idx[:, None])).sum(axis=-1)
    ranks = greater + eq_lower
    keep = ranks < jnp.asarray(n, ranks.dtype)
    return keep.reshape(w.shape).astype(w.dtype)


# ---------------------------------------------------------------------------
# Masked matmul (the Ampere sparse-tensor-core analog)
# ---------------------------------------------------------------------------

def masked_matmul(x: jax.Array, w: jax.Array, n: int, m: int) -> jax.Array:
    """x @ (Pi .* w): the sparse-inference forward hot-spot.

    x: [B, K], w: [K, F] with F % m == 0, masked along the last axis of w.
    The paper masks the weight tensor; the grouping-axis convention is pinned
    here and mirrored in rust/src/sparsity.
    """
    return x @ apply_mask(w, n, m)


# ---------------------------------------------------------------------------
# Optimizer updates
# ---------------------------------------------------------------------------

def srste_refine(g: jax.Array, w: jax.Array, mask: jax.Array, lam) -> jax.Array:
    """SR-STE gradient refinement, Eq (9): g + lam * (1 - Pi) .* w."""
    return g + lam * (1.0 - mask) * w


def adam_update(w, m, v, g, t, lr, beta1=0.9, beta2=0.999, eps=1e-8):
    """One dense Adam step, Eqs (3)-(7). ``t`` is the 1-based step index.

    Returns (w', m', v').
    """
    m1 = beta1 * m + (1.0 - beta1) * g
    v1 = beta2 * v + (1.0 - beta2) * jnp.square(g)
    t = jnp.asarray(t, dtype=w.dtype)
    mhat = m1 / (1.0 - jnp.power(jnp.asarray(beta1, w.dtype), t))
    vhat = v1 / (1.0 - jnp.power(jnp.asarray(beta2, w.dtype), t))
    w1 = w - lr * mhat / (jnp.sqrt(vhat) + eps)
    return w1, m1, v1


def step_phase2_update(w, m, v_star, g, t, lr, beta1=0.9, eps=1e-8):
    """STEP mask-learning-phase update, Alg. 1 lines 18-20.

    v_star is the frozen precondition (RAW v at the switch point, no bias
    correction -- Alg. 1 line 11 stores v_t directly and line 20 uses
    sqrt(v* + eps) with eps *inside* the sqrt, unlike the dense phase).
    Returns (w', m'); v_star is untouched by construction.
    """
    m1 = beta1 * m + (1.0 - beta1) * g
    t = jnp.asarray(t, dtype=w.dtype)
    mhat = m1 / (1.0 - jnp.power(jnp.asarray(beta1, w.dtype), t))
    w1 = w - lr * mhat / jnp.sqrt(v_star + eps)
    return w1, m1


def sgdm_update(w, buf, g, lr, momentum=0.9):
    """Momentum-SGD step (PyTorch convention): buf' = mu*buf + g; w' = w - lr*buf'."""
    buf1 = momentum * buf + g
    w1 = w - lr * buf1
    return w1, buf1


# ---------------------------------------------------------------------------
# Variance telemetry (what the rust AutoSwitch consumes)
# ---------------------------------------------------------------------------

def variance_stats(v_new: jax.Array, v_old: jax.Array):
    """Return (l1(v), l2(v), l1(v_new - v_old), d) as f32 scalars."""
    d = jnp.asarray(v_new.size, jnp.float32)
    return (
        jnp.sum(jnp.abs(v_new)).astype(jnp.float32),
        jnp.sqrt(jnp.sum(jnp.square(v_new))).astype(jnp.float32),
        jnp.sum(jnp.abs(v_new - v_old)).astype(jnp.float32),
        d,
    )


# ---------------------------------------------------------------------------
# Decaying mask schedule (Kao et al. 2022 ablation, Fig 6)
# ---------------------------------------------------------------------------

def decaying_n(step: int, m: int, decay_interval: int, start_step: int) -> int:
    """N for the decaying-mask recipe at ``step``: dense before start_step,
    then M-1, then N = max(1, floor(M / 2^k)) per decay interval k >= 1.
    """
    if step < start_step:
        return m  # dense
    k = (step - start_step) // decay_interval
    if k == 0:
        return m - 1
    return max(1, m >> k)
