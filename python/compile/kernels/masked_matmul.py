"""Pallas kernel: fused N:M-masked matmul ``x @ (Pi .* w)``.

This is the sparse-inference hot-spot the Ampere Sparse Tensor Core
accelerates in hardware. TPU adaptation (DESIGN.md SSHardware-Adaptation):
instead of WMMA consuming a compressed 2:4 operand, we fuse mask computation
and application into the RHS tile load so the MXU consumes already-masked
tiles from VMEM and the mask never round-trips to HBM. The HBM<->VMEM
schedule CUDA expresses with threadblocks is the (i, j, k) BlockSpec grid
below, k innermost so the output tile stays resident as the accumulator.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls (see DESIGN.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mask_tile(w: jax.Array, n: int, m: int) -> jax.Array:
    """N:M-mask one VMEM tile: N rounds of vectorized argmax-and-exclude."""
    rows, cols = w.shape
    groups = jnp.abs(w.reshape(rows, cols // m, m))
    selected = jnp.zeros_like(groups, dtype=jnp.bool_)
    neg = jnp.asarray(-1.0, groups.dtype)
    for _ in range(n):
        cand = jnp.where(selected, neg, groups)
        idx = jnp.argmax(cand, axis=-1)  # lowest index wins ties (= top_k)
        selected = jnp.logical_or(selected, jax.nn.one_hot(idx, m, dtype=jnp.bool_))
    return jnp.where(selected.reshape(rows, cols), w, jnp.zeros_like(w))


def _masked_matmul_kernel(x_ref, w_ref, o_ref, *, n: int, m: int, k_tiles: int):
    """Grid (i, j, k): o[i, j] += x[i, k] @ (Pi .* w)[k, j]."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    wm = _mask_tile(w_ref[...], n, m)
    o_ref[...] += jnp.dot(x_ref[...], wm, preferred_element_type=o_ref.dtype)


def masked_matmul(x: jax.Array, w: jax.Array, n: int, m: int,
                  block_b: int = 128, block_f: int = 128,
                  block_k: int = 512) -> jax.Array:
    """``x[B,K] @ (Pi .* w[K,F])`` with the N:M mask fused into the RHS load.

    Grouping matches ref.masked_matmul: last axis of w, contiguous groups of
    M. Tiles clamp to the problem size; the F tile is rounded down to a
    multiple of M so no group straddles a tile boundary; awkward shapes fall
    back to a single whole-array tile (identical lowering path).
    """
    if x.ndim != 2 or w.ndim != 2 or x.shape[1] != w.shape[0]:
        raise ValueError(f"bad shapes x={x.shape} w={w.shape}")
    if w.shape[-1] % m != 0:
        raise ValueError(f"F={w.shape[-1]} not divisible by M={m}")
    if not (1 <= n <= m):
        raise ValueError(f"need 1 <= N <= M, got N={n} M={m}")
    b, kdim = x.shape
    _, f = w.shape
    bb = min(block_b, b)
    bf = min(block_f - block_f % m or m, f)
    bk = min(block_k, kdim)
    if b % bb or f % bf or kdim % bk:
        bb, bf, bk = b, f, kdim
    k_tiles = kdim // bk
    grid = (b // bb, f // bf, k_tiles)
    return pl.pallas_call(
        functools.partial(_masked_matmul_kernel, n=n, m=m, k_tiles=k_tiles),
        out_shape=jax.ShapeDtypeStruct((b, f), x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bf), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bb, bf), lambda i, j, k: (i, j)),
        interpret=True,
    )(x, w)
