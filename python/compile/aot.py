"""AOT lowering: every (model, recipe) step function -> HLO text artifact.

Interchange format is HLO **text**, not ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published ``xla`` 0.1.6 rust crate links) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/load_hlo/.

Outputs:
  artifacts/<name>.hlo.txt   one module per artifact
  artifacts/manifest.json    input/output layouts + model param specs, the
                             single source of truth for the Rust runtime

Run via ``make artifacts`` (no-op when inputs are unchanged) - python never
runs on the training path.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import math
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import train_steps as ts
from .models import ModelSpec, registry, _init_param


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build_init_artifact(model: ModelSpec) -> ts.Artifact:
    """Param initialization as an artifact: seed (int32[1]) -> params.

    Keeps initialization on-device and seed-parameterized so the Rust
    coordinator can run many-seed experiments without Python.
    """
    def fn(seed):
        key = jax.random.PRNGKey(seed[0])
        out = []
        for spec in model.params:
            key, sub = jax.random.split(key)
            out.append(_init_param(sub, spec))
        return tuple(out)

    return ts.Artifact(
        f"{model.name}__init", fn, (jnp.zeros((1,), jnp.int32),),
        ["seed"], [f"p.{p.name}" for p in model.params],
        {"recipe": "init", "model": model.name},
    )


# Build plan: (model key, batch, seq, M values for masked recipes)
# See DESIGN.md SS3 for which experiment consumes which artifact.
PLAN = {
    "mlp_cf10": dict(batch=128, seq=None, ms=[4, 8, 16, 32], sgdm=True,
                     asp=True),
    "cnn_cf100": dict(batch=64, seq=None, ms=[4, 8, 16, 32], sgdm=True,
                      asp=True),
    "enc_glue2": dict(batch=32, seq=32, ms=[4], asp=True),
    "enc_glue3": dict(batch=32, seq=32, ms=[4], asp=True),
    "enc_stsb": dict(batch=32, seq=32, ms=[4], asp=True),
    "lm_wiki": dict(batch=16, seq=64, ms=[4], asp=True),
    "lm_wmt": dict(batch=16, seq=48, ms=[4]),
    "lm_e2e": dict(batch=8, seq=128, ms=[4]),
    "mlp_pallas": dict(batch=32, seq=None, ms=[4], asp=True, pallas=True),
}


def artifacts_for(model: ModelSpec, plan: dict):
    batch, seq = plan["batch"], plan.get("seq")
    yield build_init_artifact(model)
    yield ts.build_dense_adam(model, batch, seq)
    if plan.get("sgdm"):
        yield ts.build_dense_sgdm(model, batch, seq)
        yield ts.build_srste_sgdm(model, batch, seq, plan["ms"][0])
    for m in plan["ms"]:
        yield ts.build_srste_adam(model, batch, seq, m)
        yield ts.build_step_phase2(model, batch, seq, m)
        yield ts.build_eval(model, batch, seq, m)
        if plan.get("asp"):
            yield ts.build_asp_adam(model, batch, seq, m)
    if plan.get("pallas"):
        yield ts.build_srste_adam_pallas(model, batch, seq, 2, 4)


def spec_of(x) -> dict:
    return {"shape": list(x.shape), "dtype": str(x.dtype)}


def lower_artifact(art: ts.Artifact, out_dir: str, force: bool) -> dict:
    path = os.path.join(out_dir, f"{art.name}.hlo.txt")
    lowered = jax.jit(art.fn).lower(*art.example_args)
    outs = jax.eval_shape(art.fn, *art.example_args)
    if not isinstance(outs, tuple):
        outs = (outs,)
    entry = {
        "name": art.name,
        "path": os.path.basename(path),
        "inputs": [dict(name=n, **spec_of(a))
                   for n, a in zip(art.input_names, art.example_args)],
        "outputs": [dict(name=n, **spec_of(o))
                    for n, o in zip(art.output_names, outs)],
        "meta": art.meta,
    }
    # Always lower and compare content: a kernel/model edit must regenerate
    # the artifact even when the file exists (stale HLO is a silent
    # correctness bug on the Rust side).
    text = to_hlo_text(lowered)
    sha = hashlib.sha256(text.encode()).hexdigest()[:16]
    stale = True
    if not force and os.path.exists(path):
        with open(path, "rb") as f:
            stale = hashlib.sha256(f.read()).hexdigest()[:16] != sha
    if force or stale:
        with open(path, "w") as f:
            f.write(text)
    entry["sha256"] = sha
    return entry


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="comma-separated model keys to (re)build")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    models = registry()
    only = set(args.only.split(",")) if args.only else None
    manifest = {"artifacts": [], "models": {}}
    for key, plan in PLAN.items():
        model = models[key]
        manifest["models"][key] = {
            "params": [dict(name=p.name, shape=list(p.shape), sparse=p.sparse)
                       for p in model.params],
            "sparse_indices": model.sparse_indices,
            "kind": model.kind,
            "n_classes": model.n_classes,
            "dim": model.dim,
            "batch": plan["batch"],
            "seq": plan.get("seq"),
        }
        if only is not None and key not in only:
            # still need manifest entries for existing artifacts
            pass
        for art in artifacts_for(model, plan):
            force = args.force or (only is not None and key in only)
            entry = lower_artifact(art, args.out_dir, force=force)
            manifest["artifacts"].append(entry)
            print(f"[aot] {entry['name']}  ({entry['sha256']})", flush=True)

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {len(manifest['artifacts'])} artifacts "
          f"to {args.out_dir}/manifest.json")


if __name__ == "__main__":
    main()
