"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes / N:M ratios / magnitudes; masks must match
bit-for-bit, arithmetic to float tolerance. This is the core correctness
signal for the kernel layer (DESIGN.md SS5).
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.nm_mask import nm_mask as k_nm_mask, apply_mask as k_apply_mask
from compile.kernels.masked_matmul import masked_matmul as k_masked_matmul
from compile.kernels.optim_update import (
    adam_update as k_adam, step_phase2_update as k_step2,
    srste_refine as k_srste,
)

SETTINGS = dict(max_examples=25, deadline=None)


def nm_ratios():
    return st.sampled_from([(1, 2), (2, 2), (1, 4), (2, 4), (3, 4),
                            (1, 8), (4, 8), (7, 8), (1, 16), (8, 16),
                            (2, 32), (16, 32)])


@st.composite
def weight_matrix(draw, m_groups=True):
    n, m = draw(nm_ratios())
    rows = draw(st.integers(1, 48))
    gcols = draw(st.integers(1, 12))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(rows, gcols * m)).astype(np.float32)
    return n, m, jnp.asarray(w)


class TestNmMask:
    @given(weight_matrix())
    @settings(**SETTINGS)
    def test_matches_ref(self, case):
        n, m, w = case
        got = k_nm_mask(w, n, m)
        want = ref.nm_mask(w, n, m)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @given(weight_matrix())
    @settings(**SETTINGS)
    def test_exactly_n_per_group(self, case):
        n, m, w = case
        mask = np.asarray(k_nm_mask(w, n, m))
        groups = mask.reshape(-1, m)
        np.testing.assert_array_equal(groups.sum(axis=1),
                                      np.full(groups.shape[0], n))

    @given(weight_matrix())
    @settings(**SETTINGS)
    def test_keeps_largest(self, case):
        """Every kept entry's |w| >= every dropped entry's |w| in its group."""
        n, m, w = case
        mask = np.asarray(k_nm_mask(w, n, m)).reshape(-1, m)
        mag = np.abs(np.asarray(w)).reshape(-1, m)
        kept_min = np.where(mask > 0, mag, np.inf).min(axis=1)
        drop_max = np.where(mask == 0, mag, -np.inf).max(axis=1)
        assert (kept_min >= drop_max - 1e-12).all()

    def test_tie_break_lowest_index(self):
        w = jnp.asarray([[1.0, 1.0, 1.0, 1.0]])
        mask = np.asarray(k_nm_mask(w, 2, 4))
        np.testing.assert_array_equal(mask, [[1, 1, 0, 0]])

    def test_negative_magnitudes(self):
        w = jnp.asarray([[-5.0, 1.0, -2.0, 0.5]])
        mask = np.asarray(k_nm_mask(w, 2, 4))
        np.testing.assert_array_equal(mask, [[1, 0, 1, 0]])

    def test_multi_tile(self):
        """Shape large enough to take the multi-tile grid path."""
        rng = np.random.default_rng(7)
        w = jnp.asarray(rng.normal(size=(512, 1024)).astype(np.float32))
        got = k_nm_mask(w, 2, 4, block_rows=256, block_cols=512)
        want = ref.nm_mask(w, 2, 4)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_rejects_bad_m(self):
        w = jnp.zeros((4, 6))
        with pytest.raises(ValueError):
            k_nm_mask(w, 1, 4)
        with pytest.raises(ValueError):
            k_nm_mask(w, 0, 2)
        with pytest.raises(ValueError):
            k_nm_mask(w, 3, 2)


class TestDynamicMask:
    @given(weight_matrix())
    @settings(**SETTINGS)
    def test_dynamic_equals_static(self, case):
        n, m, w = case
        got = ref.nm_mask_dynamic(w, jnp.asarray(n, jnp.int32), m)
        want = ref.nm_mask(w, n, m)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_n_equals_m_is_dense(self):
        rng = np.random.default_rng(0)
        w = jnp.asarray(rng.normal(size=(8, 16)).astype(np.float32))
        mask = ref.nm_mask_dynamic(w, jnp.asarray(4, jnp.int32), 4)
        np.testing.assert_array_equal(np.asarray(mask), np.ones((8, 16)))

    def test_jit_dynamic_n(self):
        """One jitted artifact must serve every N (DESIGN rationale)."""
        rng = np.random.default_rng(1)
        w = jnp.asarray(rng.normal(size=(8, 16)).astype(np.float32))
        f = jax.jit(lambda w, n: ref.nm_mask_dynamic(w, n, 4))
        for n in range(1, 5):
            np.testing.assert_array_equal(
                np.asarray(f(w, jnp.asarray(n, jnp.int32))),
                np.asarray(ref.nm_mask(w, n, 4)))


class TestMaskedMatmul:
    @given(st.integers(1, 16), st.integers(1, 8), st.integers(1, 8),
           nm_ratios(), st.integers(0, 2**31 - 1))
    @settings(**SETTINGS)
    def test_matches_ref(self, b, kg, fg, nm, seed):
        n, m = nm
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(b, kg * 4)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(kg * 4, fg * m)).astype(np.float32))
        got = k_masked_matmul(x, w, n, m)
        want = ref.masked_matmul(x, w, n, m)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-5)

    def test_tiled_grid_path(self):
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.normal(size=(256, 1024)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(1024, 256)).astype(np.float32))
        got = k_masked_matmul(x, w, 2, 4, block_b=128, block_f=128, block_k=512)
        want = ref.masked_matmul(x, w, 2, 4)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=1e-3)

    def test_dense_when_n_equals_m(self):
        rng = np.random.default_rng(4)
        x = jnp.asarray(rng.normal(size=(4, 8)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(8, 8)).astype(np.float32))
        got = k_masked_matmul(x, w, 4, 4)
        np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w),
                                   rtol=1e-5, atol=1e-6)


@st.composite
def flat_state(draw):
    d = draw(st.sampled_from([8, 64, 256, 1000]))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    w, m, g = (jnp.asarray(rng.normal(size=(d,)).astype(np.float32))
               for _ in range(3))
    v = jnp.asarray(np.abs(rng.normal(size=(d,))).astype(np.float32))
    t = float(draw(st.integers(1, 10000)))
    lr = draw(st.sampled_from([1e-4, 5e-5, 1e-3]))
    return w, m, v, g, t, lr


class TestOptimKernels:
    @given(flat_state())
    @settings(**SETTINGS)
    def test_adam_matches_ref(self, s):
        w, m, v, g, t, lr = s
        got = k_adam(w, m, v, g, t, lr)
        want = ref.adam_update(w, m, v, g, t, lr)
        for a, b in zip(got, want):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-7)

    @given(flat_state())
    @settings(**SETTINGS)
    def test_step2_matches_ref(self, s):
        w, m, v, g, t, lr = s
        got = k_step2(w, m, v, g, t, lr)
        want = ref.step_phase2_update(w, m, v, g, t, lr)
        for a, b in zip(got, want):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-7)

    @given(flat_state())
    @settings(**SETTINGS)
    def test_step2_never_touches_v(self, s):
        """Freezing is structural: the kernel has no v output at all."""
        w, m, v, g, t, lr = s
        out = k_step2(w, m, v, g, t, lr)
        assert len(out) == 2  # (w', m') only

    @given(flat_state(), st.sampled_from([0.0, 2e-4, 6e-5]))
    @settings(**SETTINGS)
    def test_srste_matches_ref(self, s, lam):
        w, m, v, g, t, lr = s
        d = w.shape[0]
        mcols = 4 if d % 4 == 0 else 2
        mask = ref.nm_mask(w.reshape(-1, mcols), 1, mcols).reshape(-1)
        got = k_srste(g, w, mask, lam)
        want = ref.srste_refine(g, w, mask, lam)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-7)

    def test_srste_lambda_zero_is_identity(self):
        rng = np.random.default_rng(5)
        g = jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
        mask = ref.nm_mask(w.reshape(-1, 4), 2, 4).reshape(-1)
        np.testing.assert_array_equal(np.asarray(k_srste(g, w, mask, 0.0)),
                                      np.asarray(g))
