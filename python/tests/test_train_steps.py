"""L2 correctness: the recipe step builders (train_steps.py) against the
optimizer oracle, model zoo shape checks, and the eval metric layout the
Rust coordinator depends on.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import train_steps as ts
from compile.kernels import ref
from compile.models import registry

MODELS = registry()
MLP = MODELS["mlp_pallas"]  # tiny: fast to trace


def run_artifact(art, *args):
    out = art.fn(*(args if args else art.example_args))
    return out


def real_example(model, batch, seed=0):
    rng = np.random.default_rng(seed)
    params = model.init(seed)
    x = jnp.asarray(rng.normal(size=(batch, model.in_dim)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, model.n_classes, size=(batch,)).astype(np.int32))
    return params, x, y


class TestDenseAdam:
    def test_output_arity_matches_names(self):
        art = ts.build_dense_adam(MLP, 8, None)
        outs = run_artifact(art)
        assert len(outs) == len(art.output_names)
        assert len(art.example_args) == len(art.input_names)

    def test_single_step_matches_ref_adam(self):
        art = ts.build_dense_adam(MLP, 8, None)
        params, x, y = real_example(MLP, 8)
        P = len(params)
        zeros = [jnp.zeros_like(p) for p in params]
        outs = art.fn(*params, *zeros, *zeros, x, y,
                      jnp.asarray([1e-3], jnp.float32), jnp.asarray([1.0], jnp.float32))
        # recompute with ref: gradient of the dense loss
        def loss(ps):
            logits = MLP.apply(ps, x)
            logp = jax.nn.log_softmax(logits, -1)
            return -jnp.mean(jnp.take_along_axis(logp, y[:, None], -1))
        grads = jax.grad(loss)(params)
        for i, (p, g) in enumerate(zip(params, grads)):
            p1, m1, v1 = ref.adam_update(p, jnp.zeros_like(p), jnp.zeros_like(p),
                                         g, 1.0, 1e-3)
            np.testing.assert_allclose(outs[i], p1, rtol=1e-5, atol=1e-6)
            np.testing.assert_allclose(outs[P + i], m1, rtol=1e-5, atol=1e-6)
            np.testing.assert_allclose(outs[2 * P + i], v1, rtol=1e-5, atol=1e-7)

    def test_stats_vector_is_v_telemetry(self):
        art = ts.build_dense_adam(MLP, 8, None)
        params, x, y = real_example(MLP, 8, seed=3)
        zeros = [jnp.zeros_like(p) for p in params]
        outs = art.fn(*params, *zeros, *zeros, x, y,
                      jnp.asarray([1e-3], jnp.float32), jnp.asarray([1.0], jnp.float32))
        P = len(params)
        new_v = outs[2 * P:3 * P]
        stats = outs[-1]
        v_l1 = sum(float(jnp.sum(jnp.abs(v))) for v in new_v)
        dv_l1 = v_l1  # old v was zero
        assert stats.shape == (4,)
        np.testing.assert_allclose(float(stats[0]), v_l1, rtol=1e-5)
        np.testing.assert_allclose(float(stats[2]), dv_l1, rtol=1e-5)


class TestStepPhase2:
    def test_vstar_is_not_an_output(self):
        art = ts.build_step_phase2(MLP, 8, None, 4)
        # structural freeze: outputs are only params' + m' + loss
        P = len(MLP.params)
        assert len(art.output_names) == 2 * P + 1
        assert all(not n.startswith("vstar") for n in art.output_names)

    def test_matches_ref_update_with_mask(self):
        art = ts.build_step_phase2(MLP, 8, None, 4)
        params, x, y = real_example(MLP, 8, seed=5)
        P = len(params)
        zeros = [jnp.zeros_like(p) for p in params]
        vstar = [jnp.full_like(p, 0.02) for p in params]
        n_vec = jnp.full((len(MLP.sparse_indices),), 2, jnp.int32)
        outs = art.fn(*params, *zeros, *vstar, x, y,
                      jnp.asarray([1e-3], jnp.float32), jnp.asarray([1.0], jnp.float32),
                      jnp.asarray([0.0], jnp.float32), n_vec)

        # reference: STE gradient at masked params, then phase-2 update
        masks = []
        for spec, p in zip(MLP.params, params):
            if spec.sparse:
                masks.append(ref.nm_mask(p.reshape(-1, p.shape[-1]), 2, 4).reshape(p.shape))
            else:
                masks.append(None)

        def masked_loss(ps):
            mp = [pp if mk is None else pp + jax.lax.stop_gradient(mk * pp - pp)
                  for pp, mk in zip(ps, masks)]
            logits = MLP.apply(mp, x)
            logp = jax.nn.log_softmax(logits, -1)
            return -jnp.mean(jnp.take_along_axis(logp, y[:, None], -1))

        grads = jax.grad(masked_loss)(params)
        for i, (p, g, vs) in enumerate(zip(params, grads, vstar)):
            p1, m1 = ref.step_phase2_update(p, jnp.zeros_like(p), vs, g, 1.0, 1e-3)
            np.testing.assert_allclose(outs[i], p1, rtol=1e-4, atol=1e-6)
            np.testing.assert_allclose(outs[P + i], m1, rtol=1e-4, atol=1e-6)


class TestSrSte:
    def test_lam_zero_equals_plain_ste(self):
        art = ts.build_srste_adam(MLP, 8, None, 4)
        params, x, y = real_example(MLP, 8, seed=7)
        zeros = [jnp.zeros_like(p) for p in params]
        n_vec = jnp.full((len(MLP.sparse_indices),), 2, jnp.int32)
        lr = jnp.asarray([1e-3], jnp.float32)
        t = jnp.asarray([1.0], jnp.float32)
        out0 = art.fn(*params, *zeros, *zeros, x, y, lr, t,
                      jnp.asarray([0.0], jnp.float32), n_vec)
        out1 = art.fn(*params, *zeros, *zeros, x, y, lr, t,
                      jnp.asarray([5e-3], jnp.float32), n_vec)
        # some sparse weight tensor must differ once lam != 0
        si = MLP.sparse_indices[0]
        assert not np.allclose(out0[si], out1[si])

    def test_dense_tensors_not_refined(self):
        # lam only touches sparse tensors: bias updates identical across lam
        art = ts.build_srste_adam(MLP, 8, None, 4)
        params, x, y = real_example(MLP, 8, seed=9)
        zeros = [jnp.zeros_like(p) for p in params]
        n_vec = jnp.full((len(MLP.sparse_indices),), 2, jnp.int32)
        lr = jnp.asarray([1e-3], jnp.float32)
        t = jnp.asarray([1.0], jnp.float32)
        outs = [art.fn(*params, *zeros, *zeros, x, y, lr, t,
                       jnp.asarray([lam], jnp.float32), n_vec)
                for lam in (0.0, 1.0)]
        dense_idx = [i for i, s in enumerate(MLP.params) if not s.sparse]
        for i in dense_idx:
            np.testing.assert_array_equal(outs[0][i], outs[1][i])


class TestAsp:
    def test_projection_keeps_support(self):
        art = ts.build_asp_adam(MLP, 8, None, 4)
        params, x, y = real_example(MLP, 8, seed=11)
        zeros = [jnp.zeros_like(p) for p in params]
        n_vec = jnp.full((len(MLP.sparse_indices),), 2, jnp.int32)
        outs = art.fn(*params, *zeros, *zeros, x, y,
                      jnp.asarray([1e-3], jnp.float32), jnp.asarray([1.0], jnp.float32),
                      n_vec)
        for si in MLP.sparse_indices:
            w1 = np.asarray(outs[si])
            groups = w1.reshape(-1, 4)
            nonzero = (groups != 0).sum(axis=1)
            assert (nonzero <= 2).all(), "ASP weights must stay 2:4-supported"


class TestEval:
    def test_classify_metrics_layout(self):
        art = ts.build_eval(MLP, 8, None, 4)
        params, x, y = real_example(MLP, 8, seed=13)
        n_vec = jnp.full((len(MLP.sparse_indices),), 4, jnp.int32)  # dense
        loss, metrics = art.fn(*params, x, y, n_vec)
        assert metrics.shape == (8,)
        correct, count, tp, fp, tn, fn = (float(metrics[i]) for i in range(6))
        assert count == 8.0
        assert 0 <= correct <= 8
        # confusion identity: tp+fp+tn+fn == count
        assert tp + fp + tn + fn == count
        # accuracy from confusion consistent for the class-1 slice
        logits = MLP.apply(params, x)
        pred = np.argmax(np.asarray(logits), -1)
        yy = np.asarray(y)
        assert tp == ((pred == 1) & (yy == 1)).sum()
        assert float(loss[0]) > 0

    def test_dense_eval_equals_n_eq_m(self):
        art = ts.build_eval(MLP, 8, None, 4)
        params, x, y = real_example(MLP, 8, seed=15)
        S = len(MLP.sparse_indices)
        l_dense, _ = art.fn(*params, x, y, jnp.full((S,), 4, jnp.int32))
        # host-side dense forward
        logits = MLP.apply(params, x)
        logp = jax.nn.log_softmax(logits, -1)
        expect = -jnp.mean(jnp.take_along_axis(logp, y[:, None], -1))
        np.testing.assert_allclose(float(l_dense[0]), float(expect), rtol=1e-5)

    def test_masked_eval_changes_loss(self):
        art = ts.build_eval(MLP, 8, None, 4)
        params, x, y = real_example(MLP, 8, seed=17)
        S = len(MLP.sparse_indices)
        l_dense, _ = art.fn(*params, x, y, jnp.full((S,), 4, jnp.int32))
        l_masked, _ = art.fn(*params, x, y, jnp.full((S,), 1, jnp.int32))
        assert float(l_dense[0]) != float(l_masked[0])


class TestModels:
    @pytest.mark.parametrize("key", ["mlp_cf10", "mlp_pallas"])
    def test_mlp_apply_shapes(self, key):
        model = MODELS[key]
        params = model.init(0)
        x = jnp.zeros((4, model.in_dim), jnp.float32)
        out = model.apply(params, x)
        assert out.shape == (4, model.n_classes)

    @pytest.mark.parametrize("key", ["lm_wiki", "lm_wmt"])
    def test_lm_apply_shapes(self, key):
        model = MODELS[key]
        params = model.init(0)
        seq = 16
        x = jnp.zeros((2, seq), jnp.int32)
        out = model.apply(params, x)
        assert out.shape == (2, seq, model.n_classes)

    def test_encoder_apply_shapes(self):
        model = MODELS["enc_glue3"]
        params = model.init(0)
        out = model.apply(params, jnp.zeros((2, 32), jnp.int32))
        assert out.shape == (2, 3)

    def test_init_deterministic(self):
        a = MLP.init(42)
        b = MLP.init(42)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)
        c = MLP.init(43)
        assert not np.allclose(a[0], c[0])

    def test_sparse_indices_last_dims_divide_32(self):
        # every sparse-eligible tensor must support the full M grid
        for key, model in MODELS.items():
            for i in model.sparse_indices:
                shape = model.params[i].shape
                assert shape[-1] % 4 == 0, f"{key} param {i} last dim {shape[-1]}"


class TestDecayingN:
    def test_matches_paper_schedule(self):
        assert ref.decaying_n(0, 8, 10, 5) == 8
        assert ref.decaying_n(5, 8, 10, 5) == 7
        assert ref.decaying_n(15, 8, 10, 5) == 4
        assert ref.decaying_n(25, 8, 10, 5) == 2
        assert ref.decaying_n(35, 8, 10, 5) == 1
        assert ref.decaying_n(9999, 8, 10, 5) == 1


class TestPerfModel:
    def test_all_shipped_tiles_fit_vmem(self):
        from compile import perf_model as pm
        rows = [
            pm.nm_mask_model(256, 512, 4),
            pm.nm_mask_model(256, 512, 32),
            pm.masked_matmul_model(128, 128, 512),
            pm.masked_matmul_model(256, 256, 1024),
            pm.optim_update_model(),
        ]
        assert all(r["ok"] for r in rows)
        mm = pm.masked_matmul_model(128, 128, 512)
        assert mm["mxu_util_dense"] == 1.0  # MXU-aligned tiles

    def test_unaligned_tile_flags_low_utilization(self):
        from compile import perf_model as pm
        mm = pm.masked_matmul_model(bm=100, bn=100, bk=512)
        assert mm["mxu_util_dense"] < 0.7

    def test_oversized_tile_flagged(self):
        from compile import perf_model as pm
        r = pm.masked_matmul_model(bm=1024, bn=2048, bk=4096)
        assert not r["ok"]
